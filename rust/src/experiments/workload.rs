//! Workload construction: RunConfig → (engine, client shards, test set).
//!
//! This is the launcher's glue: builds the synthetic dataset for the task,
//! partitions it to the configured EMD, assembles the eval set at the
//! model's batch size, and instantiates the engine (PJRT artifacts or the
//! native mock).

use crate::config::{EngineKind, RunConfig, Task};
use crate::data::dataset::{Batch, Dataset};
use crate::data::partition::partition_by_emd;
use crate::data::shakespeare::Shakespeare;
use crate::data::synth_cifar::{CifarLike, OwnedCifarShard, NUM_CLASSES, PIXELS};
use crate::runtime::manifest::Manifest;
use crate::runtime::native::NativeEngine;
use crate::runtime::pjrt::{PjrtContext, PjrtEngine};
use crate::runtime::TrainEngine;
use anyhow::{anyhow, Result};
use std::path::Path;
use std::rc::Rc;
use std::sync::Arc;

pub struct Workload {
    pub shards: Vec<Box<dyn Dataset + Send>>,
    pub test: Vec<Batch>,
    /// realized non-IID-ness (label EMD for cifar, char EMD for shakespeare)
    pub achieved_emd: f64,
}

/// Build the data side of a run.
pub fn build_workload(cfg: &RunConfig) -> Result<Workload> {
    match cfg.task {
        Task::Cifar => {
            let per_class = (cfg.clients * cfg.samples_per_client).div_ceil(NUM_CLASSES);
            let train = Arc::new(CifarLike::balanced(per_class, 0.15, cfg.seed));
            let (shards, achieved) =
                partition_by_emd(&train.labels, NUM_CLASSES, cfg.clients, cfg.emd, cfg.seed)
                    .map_err(|e| anyhow!(e))?;
            let shards: Vec<Box<dyn Dataset + Send>> = shards
                .into_iter()
                .map(|s| {
                    Box::new(OwnedCifarShard { parent: train.clone(), ids: s.sample_ids })
                        as Box<dyn Dataset + Send>
                })
                .collect();
            let per_class = cfg.test_size.div_ceil(NUM_CLASSES);
            let test_ds = CifarLike::balanced(per_class, 0.15, cfg.seed ^ 0x7E57);
            let test = test_ds.eval_batches(cfg.batch);
            Ok(Workload { shards, test, achieved_emd: achieved })
        }
        Task::Shakespeare => {
            let corpus = Shakespeare::generate(
                cfg.clients,
                cfg.samples_per_client,
                20,
                Shakespeare::PAPER_BIAS,
                cfg.seed,
            );
            let achieved = corpus.char_emd();
            let (train, test_streams) = corpus.split_owned(0.2);
            let shards: Vec<Box<dyn Dataset + Send>> = train
                .into_iter()
                .map(|s| Box::new(s) as Box<dyn Dataset + Send>)
                .collect();
            // eval set: windows pooled across speakers (single speakers may
            // hold fewer windows than one batch), capped at test_size
            let seq = 20usize;
            let max_windows = cfg.test_size.max(cfg.batch);
            let mut xs: Vec<i32> = Vec::new();
            let mut ys: Vec<i32> = Vec::new();
            let mut windows = 0usize;
            'outer: for stream in &test_streams {
                let mut s = 0;
                while s + seq + 1 <= stream.tokens.len() {
                    xs.extend_from_slice(&stream.tokens[s..s + seq]);
                    ys.extend_from_slice(&stream.tokens[s + 1..s + seq + 1]);
                    windows += 1;
                    s += seq;
                    if windows >= max_windows {
                        break 'outer;
                    }
                }
            }
            let mut test = Vec::new();
            let full = windows - windows % cfg.batch;
            for b in 0..full / cfg.batch {
                let lo = b * cfg.batch * seq;
                let hi = (b + 1) * cfg.batch * seq;
                test.push(Batch::Tokens {
                    x: xs[lo..hi].to_vec(),
                    y: ys[lo..hi].to_vec(),
                    n: cfg.batch,
                    seq,
                });
            }
            Ok(Workload { shards, test, achieved_emd: achieved })
        }
        Task::Blobs => {
            use crate::runtime::native::BlobDataset;
            let mut shards: Vec<Box<dyn Dataset + Send>> = Vec::new();
            for c in 0..cfg.clients {
                shards.push(Box::new(BlobDataset::generate_split(
                    cfg.samples_per_client,
                    16,
                    4,
                    0.4,
                    cfg.seed,
                    cfg.seed + 1 + c as u64,
                )));
            }
            let test_ds = crate::runtime::native::BlobDataset::generate_split(
                cfg.test_size.max(cfg.batch),
                16,
                4,
                0.4,
                cfg.seed,
                cfg.seed ^ 0x7E57,
            );
            let test = test_ds.eval_batches(cfg.batch);
            Ok(Workload { shards, test, achieved_emd: 0.0 })
        }
    }
}

/// Everything one `fedgmf verify` scenario run needs: deterministic blob
/// shards, a four-tier link fleet, and a fresh native engine.
pub struct VerifyFixture {
    pub shards: Vec<Box<dyn Dataset + Send>>,
    pub network: crate::sim::network::Network,
    pub engine: NativeEngine,
}

/// Tiny-scale deterministic fixture for the conformance matrix
/// (`crate::testkit`): `clients` blob shards over shared class centers
/// (same task, disjoint per-client noise), no eval set (the trajectory is
/// pinned through losses and parameter bits), and a hub network whose
/// uplink tiers repeat every 4 clients. The slowest tier
/// (`up_bps = 1200`) cannot meet the fixture deadline under **any** codec
/// axis — even the ~150-byte q8 upload takes ≥ 0.12 s — so every scenario
/// that can produce stragglers does, and the carry policies genuinely
/// diverge from drop. Everything is a pure function of `seed`.
pub fn verify_fixture(clients: usize, seed: u64) -> VerifyFixture {
    use crate::runtime::native::BlobDataset;
    use crate::sim::network::{LinkSpec, Network};
    const DIM: usize = 16;
    const CLASSES: usize = 4;
    const PER_CLIENT: usize = 40;
    let shards: Vec<Box<dyn Dataset + Send>> = (0..clients)
        .map(|c| {
            Box::new(BlobDataset::generate_split(
                PER_CLIENT,
                DIM,
                CLASSES,
                0.4,
                seed,
                seed + 1 + c as u64,
            )) as Box<dyn Dataset + Send>
        })
        .collect();
    let links: Vec<LinkSpec> = (0..clients)
        .map(|i| LinkSpec {
            up_bps: [24_000.0, 12_000.0, 8_000.0, 1_200.0][i % 4],
            down_bps: 96_000.0,
            latency_s: 0.004 + 0.002 * (i % 3) as f64,
        })
        .collect();
    VerifyFixture {
        shards,
        network: Network { links },
        engine: NativeEngine::new(DIM, 12, CLASSES, seed),
    }
}

/// Build the engine side of a run.
pub fn build_engine(
    cfg: &RunConfig,
    artifacts: &Path,
    ctx: &mut Option<Rc<PjrtContext>>,
) -> Result<Box<dyn TrainEngine>> {
    match (cfg.engine, cfg.task) {
        (EngineKind::Pjrt, Task::Blobs) => Err(anyhow!("blobs task requires the native engine")),
        (EngineKind::Pjrt, _) => {
            let man = Manifest::load(artifacts)?;
            let entry = man.model(&cfg.model)?;
            if entry.batch != cfg.batch {
                return Err(anyhow!(
                    "config batch {} != artifact batch {} for model {} (re-export or set train.batch)",
                    cfg.batch,
                    entry.batch,
                    cfg.model
                ));
            }
            if ctx.is_none() {
                *ctx = Some(PjrtContext::cpu()?);
            }
            Ok(Box::new(PjrtEngine::new(ctx.as_ref().unwrap().clone(), entry)?))
        }
        (EngineKind::Native, Task::Cifar) => {
            Ok(Box::new(NativeEngine::new(PIXELS, 24, NUM_CLASSES, cfg.seed)))
        }
        (EngineKind::Native, Task::Blobs) => Ok(Box::new(NativeEngine::new(16, 16, 4, cfg.seed))),
        (EngineKind::Native, Task::Shakespeare) => {
            Err(anyhow!("shakespeare requires the pjrt engine (LSTM artifact)"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    #[test]
    fn cifar_workload_shapes() {
        // EMD targeting assumes clients >= classes (paper: 20 clients / 10
        // classes) so the dominant-class assignment covers every class
        let mut cfg = RunConfig::default();
        cfg.clients = 10;
        cfg.samples_per_client = 40;
        cfg.test_size = 64;
        cfg.emd = 0.99;
        let w = build_workload(&cfg).unwrap();
        assert_eq!(w.shards.len(), 10);
        assert!((w.achieved_emd - 0.99).abs() < 0.12, "emd {}", w.achieved_emd);
        assert!(!w.test.is_empty());
        let total: usize = w.shards.iter().map(|s| s.len()).sum();
        assert!(total >= 200);
    }

    #[test]
    fn shakespeare_workload_shapes() {
        let mut cfg = RunConfig::shakespeare();
        cfg.clients = 8;
        cfg.samples_per_client = 800;
        cfg.test_size = 64;
        let w = build_workload(&cfg).unwrap();
        assert_eq!(w.shards.len(), 8);
        assert!(w.achieved_emd > 0.02 && w.achieved_emd < 0.4, "emd {}", w.achieved_emd);
        assert!(!w.test.is_empty());
    }

    #[test]
    fn verify_fixture_is_deterministic_and_has_a_hopeless_tier() {
        let a = verify_fixture(10, 42);
        let b = verify_fixture(10, 42);
        assert_eq!(a.shards.len(), 10);
        assert_eq!(a.network.links.len(), 10);
        assert_eq!(a.engine.param_count(), b.engine.param_count());
        for (la, lb) in a.network.links.iter().zip(&b.network.links) {
            assert_eq!(la.up_bps.to_bits(), lb.up_bps.to_bits());
            assert_eq!(la.latency_s.to_bits(), lb.latency_s.to_bits());
        }
        // the slowest tier cannot ship even a minimal ~150-byte upload
        // inside the testkit deadline (0.095 s): 150 / 1200 = 0.125 s
        let slowest = a.network.links.iter().map(|l| l.up_bps).fold(f64::MAX, f64::min);
        assert!(150.0 / slowest > 0.095, "slowest tier must straggle under every codec");
    }

    #[test]
    fn native_cifar_engine_works_end_to_end() {
        let mut cfg = RunConfig::default();
        cfg.engine = EngineKind::Native;
        cfg.clients = 3;
        cfg.samples_per_client = 30;
        cfg.test_size = 32;
        let w = build_workload(&cfg).unwrap();
        let mut ctx = None;
        let mut engine = build_engine(&cfg, Path::new("/nonexistent"), &mut ctx).unwrap();
        let mut rng = crate::util::rng::Rng::new(0);
        let batch = w.shards[0].sample_batch(cfg.batch, &mut rng);
        let params = engine.initial_params();
        let out = engine.train_step(&params, &batch).unwrap();
        assert!(out.loss > 0.0);
    }
}
