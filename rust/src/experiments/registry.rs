//! The experiment registry: table1/table2/table3/table4/fig4/fig5/fig6.

use super::runner::{comparison_rows, execute, execute_with, write_curve};
use crate::compress::CompressorKind;
use crate::config::{EngineKind, RunConfig, Scale, Task};
use crate::coordinator::round::RunSummary;
use crate::data::partition::PAPER_EMD_LEVELS;
use crate::runtime::pjrt::PjrtContext;
use crate::sim::scheduler::{ProfilePreset, SelectionPolicy, SimConfig, StalenessPolicy};
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::fmt::Write as _;
use std::path::PathBuf;
use std::rc::Rc;

/// CLI-facing arguments common to all experiments.
#[derive(Clone, Debug)]
pub struct ExpArgs {
    pub scale: Scale,
    pub engine: Option<EngineKind>,
    pub artifacts: PathBuf,
    pub out_dir: PathBuf,
    pub seed: u64,
    /// restrict to a subset of techniques (empty = all four)
    pub techniques: Vec<CompressorKind>,
    /// restrict EMD levels (table3), rates (fig5/6), τ values (ablation_tau)
    /// or simulated-seconds budgets (time_to_accuracy); empty = default grid
    pub levels: Vec<f64>,
}

impl ExpArgs {
    pub fn new(artifacts: PathBuf, out_dir: PathBuf) -> Self {
        ExpArgs {
            scale: Scale::Default,
            engine: None,
            artifacts,
            out_dir,
            seed: 42,
            techniques: Vec::new(),
            levels: Vec::new(),
        }
    }

    fn techs(&self) -> Vec<CompressorKind> {
        if self.techniques.is_empty() {
            CompressorKind::ALL.to_vec()
        } else {
            self.techniques.clone()
        }
    }

    fn base_cfg(&self, task: Task) -> RunConfig {
        let mut cfg = match task {
            Task::Shakespeare => RunConfig::shakespeare(),
            _ => RunConfig::default(),
        };
        cfg.task = task;
        cfg = cfg.with_scale(self.scale);
        cfg.seed = self.seed;
        if let Some(e) = self.engine {
            cfg.engine = e;
        }
        cfg
    }
}

pub const EXPERIMENTS: [(&str, &str); 10] = [
    ("table1", "Setup summary of both tasks (paper Table 1)"),
    ("table2", "Technique comparison matrix (paper Table 2)"),
    ("table3", "CIFAR: acc + comm across 7 EMD levels, rate 0.1 (paper Table 3)"),
    ("fig4", "CIFAR EMD=1.35: accuracy curves per round (paper Fig. 4)"),
    ("fig5", "CIFAR EMD=1.35: acc + comm vs compression rate (paper Fig. 5)"),
    ("table4", "Shakespeare: acc + comm, rate 0.1 (paper Table 4)"),
    ("fig6", "Shakespeare: acc + comm vs compression rate (paper Fig. 6)"),
    ("ablation_tau", "DGCwGMF fusion-ratio ablation on Cifar10-6 (design-choice study)"),
    (
        "time_to_accuracy",
        "CIFAR under the deadline scheduler: accuracy at simulated-seconds budgets, \
         plus adaptive rate control vs fixed rates on a longtail fleet",
    ),
    (
        "staleness_sweep",
        "Semi-sync aggregation: drop vs carry vs discounted carry on a longtail fleet",
    ),
];

pub fn list() -> String {
    let mut out = String::from("available experiments:\n");
    for (id, desc) in EXPERIMENTS {
        let _ = writeln!(out, "  {id:<8} {desc}");
    }
    out
}

/// Run an experiment by id; returns the printed report.
pub fn run(id: &str, args: &ExpArgs) -> Result<String> {
    std::fs::create_dir_all(args.out_dir.join(id))?;
    match id {
        "table1" => table1(args),
        "table2" => Ok(table2()),
        "table3" => table3(args),
        "fig4" => fig4(args),
        "fig5" => fig5(args),
        "table4" => table4(args),
        "fig6" => fig6(args),
        "ablation_tau" => ablation_tau(args),
        "time_to_accuracy" => time_to_accuracy(args),
        "staleness_sweep" => staleness_sweep(args),
        other => Err(anyhow!("unknown experiment `{other}`\n{}", list())),
    }
}

// ------------------------------------------------------------------ table1

fn table1(args: &ExpArgs) -> Result<String> {
    let c = args.base_cfg(Task::Cifar);
    let s = args.base_cfg(Task::Shakespeare);
    let mut out = String::from("Table 1 — Summary of tasks (resolved configuration)\n\n");
    let row3 = |out: &mut String, a: &str, b: &str, c: &str| {
        let _ = writeln!(out, "{a:<16} {b:<28} {c:<28}");
    };
    row3(&mut out, "", "Image Classification", "Next-Word Prediction");
    row3(&mut out, "Dataset", "Mod-Cifar10 (synthetic)", "Shakespeare (synthetic)");
    row3(&mut out, "Model", &c.model, &s.model);
    row3(&mut out, "# of clients", &c.clients.to_string(), &s.clients.to_string());
    row3(&mut out, "# of rounds", &c.rounds.to_string(), &s.rounds.to_string());
    let _ = writeln!(
        out,
        "\n(paper values: ResNet56 / 20 clients / 220 rounds and LSTM / 100 / 80;\n scale \
         `{:?}` — use --scale paper for the full grid)",
        args.scale
    );
    Ok(out)
}

// ------------------------------------------------------------------ table2

fn table2() -> String {
    let mut out = String::from("Table 2 — Techniques in our experiments\n\n");
    let _ = writeln!(
        out,
        "{:<10} {:<20} {:<30} {:<22}",
        "Technique",
        "Momentum Correction",
        "Client-side Global Momentum",
        "Server-side Global Momentum"
    );
    for kind in CompressorKind::ALL {
        let row = kind.technique_row();
        let _ = writeln!(
            out,
            "{:<10} {:<20} {:<30} {:<22}",
            kind.name(),
            if row.momentum_correction { "v" } else { "" },
            row.client_gm.map(|w| format!("v (in {w} process)")).unwrap_or_default(),
            if row.server_gm { "v" } else { "" },
        );
    }
    out
}

// ------------------------------------------------------------------ table3

fn table3(args: &ExpArgs) -> Result<String> {
    let levels: Vec<f64> =
        if args.levels.is_empty() { PAPER_EMD_LEVELS.to_vec() } else { args.levels.clone() };
    let mut ctx: Option<Rc<PjrtContext>> = None;
    let mut out = String::from(
        "Table 3 — Image classification, compression rate 0.1\n(synthetic Mod-Cifar10; orderings/deltas are the reproduction target)\n",
    );
    let mut all_json = Vec::new();
    for (i, &emd) in levels.iter().enumerate() {
        let mut rows: Vec<(String, RunSummary)> = Vec::new();
        let mut achieved = 0.0;
        for kind in args.techs() {
            let mut cfg = args.base_cfg(Task::Cifar);
            cfg.technique = kind;
            cfg.emd = emd;
            let (summary, a) = execute(&cfg, &args.artifacts, &mut ctx)?;
            achieved = a;
            let curve_name = format!("emd{emd}_{}", kind.name());
            write_curve(&summary, &args.out_dir.join("table3"), &curve_name)?;
            all_json.push(summary_json(&format!("cifar{i}"), emd, &summary));
            eprintln!(
                "[table3] EMD={emd} {} done: acc={:.4} traffic={:.4} GB",
                kind.name(),
                summary.final_accuracy,
                summary.total_traffic_gb
            );
            rows.push((kind.name().to_string(), summary));
        }
        let _ = writeln!(out, "\nCifar10-{i} (EMD target {emd}, achieved {achieved:.3})");
        out.push_str(&comparison_rows(&rows));
    }
    std::fs::write(
        args.out_dir.join("table3").join("summary.json"),
        Json::Arr(all_json).to_pretty(),
    )?;
    Ok(out)
}

fn summary_json(dataset: &str, level: f64, s: &RunSummary) -> Json {
    Json::obj(vec![
        ("dataset", Json::str(dataset)),
        ("level", Json::num(level)),
        ("technique", Json::str(s.technique)),
        ("final_accuracy", Json::num(s.final_accuracy)),
        ("best_accuracy", Json::num(s.best_accuracy)),
        ("traffic_gb", Json::num(s.total_traffic_gb)),
        ("uplink_gb", Json::num(s.uplink_gb)),
        ("downlink_gb", Json::num(s.downlink_gb)),
        ("sim_seconds", Json::num(s.sim_seconds)),
        ("mask_overlap", Json::num(s.mean_mask_overlap)),
    ])
}

// -------------------------------------------------------------------- fig4

fn fig4(args: &ExpArgs) -> Result<String> {
    let mut ctx: Option<Rc<PjrtContext>> = None;
    let mut out =
        String::from("Fig. 4 — Top-1 accuracy curves on Cifar10-6 (EMD 1.35), rate 0.1\n\n");
    let dir = args.out_dir.join("fig4");
    let mut rows = Vec::new();
    for kind in args.techs() {
        let mut cfg = args.base_cfg(Task::Cifar);
        cfg.technique = kind;
        cfg.emd = 1.35;
        cfg.eval_every = (cfg.rounds / 10).max(1); // dense curve for the figure
        let (summary, _) = execute(&cfg, &args.artifacts, &mut ctx)?;
        write_curve(&summary, &dir, kind.name())?;
        eprintln!("[fig4] {} done: final acc {:.4}", kind.name(), summary.final_accuracy);
        let series: Vec<String> = summary
            .recorder
            .rounds
            .iter()
            .filter(|r| r.test_accuracy > 0.0)
            .map(|r| format!("({}, {:.3})", r.round, r.test_accuracy))
            .collect();
        let _ = writeln!(out, "{:<10} {}", kind.name(), series.join(" "));
        rows.push((kind.name().to_string(), summary));
    }
    out.push('\n');
    out.push_str(&comparison_rows(&rows));
    out.push_str("\ncurves: results/fig4/<technique>.csv (round,test_accuracy,...)\n");
    Ok(out)
}

// -------------------------------------------------------------------- fig5

fn fig5(args: &ExpArgs) -> Result<String> {
    sweep_rates(
        args,
        Task::Cifar,
        "fig5",
        "Fig. 5 — accuracy & comm vs compression rate, Cifar10-6 (EMD 1.35)",
    )
}

// ------------------------------------------------------------------ table4

fn table4(args: &ExpArgs) -> Result<String> {
    let mut ctx: Option<Rc<PjrtContext>> = None;
    let mut out = String::from(
        "Table 4 — Next-word (next-char) prediction, Shakespeare, rate 0.1\n",
    );
    let mut rows = Vec::new();
    let mut all_json = Vec::new();
    let mut achieved = 0.0;
    for kind in args.techs() {
        let mut cfg = args.base_cfg(Task::Shakespeare);
        cfg.technique = kind;
        let (summary, a) = execute(&cfg, &args.artifacts, &mut ctx)?;
        achieved = a;
        write_curve(&summary, &args.out_dir.join("table4"), kind.name())?;
        all_json.push(summary_json("shakespeare", a, &summary));
        eprintln!(
            "[table4] {} done: acc={:.4} traffic={:.4} GB",
            kind.name(),
            summary.final_accuracy,
            summary.total_traffic_gb
        );
        rows.push((kind.name().to_string(), summary));
    }
    let _ = writeln!(out, "(char-level EMD achieved: {achieved:.4}; paper: 0.1157)\n");
    out.push_str(&comparison_rows(&rows));
    std::fs::write(
        args.out_dir.join("table4").join("summary.json"),
        Json::Arr(all_json).to_pretty(),
    )?;
    Ok(out)
}

// -------------------------------------------------------------------- fig6

fn fig6(args: &ExpArgs) -> Result<String> {
    sweep_rates(
        args,
        Task::Shakespeare,
        "fig6",
        "Fig. 6 — accuracy & comm vs compression rate, Shakespeare",
    )
}

// ------------------------------------------------------------ ablation_tau

/// Design-choice ablation: DGCwGMF with the fusion ratio held constant at
/// several values (τ=0 is exactly DGC). Shows the accuracy ↔ mask-overlap
/// ↔ downlink trade-off the paper's §3 narrates ("a smaller τ fits local
/// data, a larger τ waives parameters that differ from the global
/// momentum") and justifies the stepped 0→0.6 schedule.
fn ablation_tau(args: &ExpArgs) -> Result<String> {
    let taus: Vec<f64> = if args.levels.is_empty() {
        vec![0.0, 0.2, 0.4, 0.6, 0.8, 1.0]
    } else {
        args.levels.clone()
    };
    let mut ctx: Option<Rc<PjrtContext>> = None;
    let mut out = String::from(
        "Ablation — constant fusion ratio τ, DGCwGMF on Cifar10-6 (EMD 1.35), rate 0.1\n\n",
    );
    let mut csv = String::from("tau,final_accuracy,traffic_gb,downlink_gb,mask_overlap\n");
    let _ = writeln!(
        out,
        "{:<6} {:>10} {:>12} {:>10} {:>9}",
        "tau", "accuracy", "traffic(GB)", "down(GB)", "overlap"
    );
    for &tau in &taus {
        let mut cfg = args.base_cfg(Task::Cifar);
        cfg.technique = CompressorKind::DgcWgmf;
        cfg.emd = 1.35;
        cfg.tau_end = tau as f32;
        cfg.tau_steps = 0; // steps=0 → constant τ from round 0 (isolates τ)
        let (s, _) = execute(&cfg, &args.artifacts, &mut ctx)?;
        eprintln!(
            "[ablation_tau] tau={tau}: acc={:.4} overlap={:.3}",
            s.final_accuracy, s.mean_mask_overlap
        );
        let _ = writeln!(
            out,
            "{:<6} {:>10.4} {:>12.4} {:>10.4} {:>9.3}",
            tau, s.final_accuracy, s.total_traffic_gb, s.downlink_gb, s.mean_mask_overlap
        );
        let _ = writeln!(
            csv,
            "{tau},{:.6},{:.6},{:.6},{:.6}",
            s.final_accuracy, s.total_traffic_gb, s.downlink_gb, s.mean_mask_overlap
        );
    }
    std::fs::write(args.out_dir.join("ablation_tau").join("sweep.csv"), csv)?;
    out.push_str(
        "\nexpected: overlap rises monotonically with τ and downlink falls monotonically;\n\
         accuracy is workload- and horizon-dependent (see EXPERIMENTS.md §Ablation).\n",
    );
    Ok(out)
}

// ------------------------------------------------------ time_to_accuracy

/// Wall-clock regime the paper's bytes tables cannot show: a heterogeneous
/// fleet (every 4th client 8× slower on link *and* compute) under a 0.25 s
/// round deadline, 2% hard dropouts, and 1.25× cohort over-selection. Every
/// scheme runs the same simulated clock; the table reports accuracy reached
/// at fixed simulated-seconds budgets plus what the deadline cost (dropped
/// uploads, wasted straggler bytes). `--levels` supplies absolute budgets in
/// seconds (the run stops at the largest); by default each scheme runs its
/// full round count and budgets are 25/50/100% of the slowest scheme's
/// total simulated time.
///
/// A second leg compares *rate policies* on a longtail fleet: DGCwGMF at
/// fixed rates 0.05/0.10/0.25 vs the adaptive per-client controller
/// (`[rate_control]`, seeded at 0.10), reporting the uplink each policy
/// spent to reach the common accuracy target — the adaptive policy's
/// whole claim is reaching it on fewer bytes than every fixed rate.
fn time_to_accuracy(args: &ExpArgs) -> Result<String> {
    let mut ctx: Option<Rc<PjrtContext>> = None;
    let dir = args.out_dir.join("time_to_accuracy");
    let sim = SimConfig {
        preset: ProfilePreset::Heterogeneous { slow_every: 4, slow_factor: 8.0 },
        deadline_s: 0.25,
        dropout: 0.02,
        overselect: 1.25,
        compute_s: 0.05,
        ..Default::default()
    };
    let explicit_budget = args
        .levels
        .iter()
        .copied()
        .fold(None, |m: Option<f64>, b| Some(m.map_or(b, |x: f64| x.max(b))));
    let mut rows: Vec<(String, RunSummary)> = Vec::new();
    let mut out = String::from(
        "Time-to-accuracy — heterogeneous fleet under a 0.25 s round deadline\n(every 4th client 8x slower; 2% dropout; 1.25x over-selection; rate 0.1, EMD 1.35)\n\n",
    );
    for kind in args.techs() {
        let mut cfg = args.base_cfg(Task::Cifar);
        cfg.technique = kind;
        cfg.emd = 1.35;
        cfg.client_fraction = 0.75; // headroom for the over-selection
        cfg.eval_every = (cfg.rounds / 10).max(1); // dense curve for budget cuts
        cfg.sim = sim;
        let (summary, _) = execute_with(&cfg, &args.artifacts, &mut ctx, explicit_budget)?;
        write_curve(&summary, &dir, kind.name())?;
        eprintln!(
            "[time_to_accuracy] {} done: acc={:.4} sim={:.1}s dropped late={} offline={}",
            kind.name(),
            summary.final_accuracy,
            summary.sim_seconds,
            summary.dropped_deadline,
            summary.dropped_offline
        );
        rows.push((kind.name().to_string(), summary));
    }
    let budgets: Vec<f64> = if args.levels.is_empty() {
        let t = rows.iter().map(|(_, s)| s.sim_seconds).fold(0.0, f64::max);
        vec![t * 0.25, t * 0.5, t]
    } else {
        args.levels.clone()
    };
    let mut csv = String::from(
        "technique,budget_s,accuracy,rounds,dropped_deadline,dropped_offline,wasted_uplink_gb,traffic_gb\n",
    );
    let _ = writeln!(
        out,
        "{:<10} {:>10} {:>11} {:>7} {:>6} {:>8} {:>11}",
        "Technique", "budget(s)", "acc@budget", "rounds", "late", "offline", "wasted(GB)"
    );
    for (name, s) in &rows {
        for &b in &budgets {
            // every statistic in a budget row is cut at that budget
            let in_budget = s.recorder.rounds.iter().filter(|r| r.sim_clock <= b);
            let (mut rounds, mut late, mut offline) = (0usize, 0usize, 0usize);
            let (mut wasted, mut traffic) = (0usize, 0usize);
            for r in in_budget {
                rounds += 1;
                late += r.dropped_deadline;
                offline += r.dropped_offline;
                wasted += r.wasted_uplink_bytes;
                traffic += r.uplink_bytes + r.downlink_bytes;
            }
            let acc = s.recorder.accuracy_at_sim_seconds(b);
            let wasted_gb = wasted as f64 / 1e9;
            let traffic_gb = traffic as f64 / 1e9;
            let _ = writeln!(
                out,
                "{:<10} {:>10.1} {:>11.4} {:>7} {:>6} {:>8} {:>11.4}",
                name, b, acc, rounds, late, offline, wasted_gb
            );
            let _ = writeln!(
                csv,
                "{name},{b:.3},{acc:.6},{rounds},{late},{offline},{wasted_gb:.6},{traffic_gb:.6}"
            );
        }
    }
    std::fs::write(dir.join("budgets.csv"), csv)?;
    out.push_str(
        "\ncurves: results/time_to_accuracy/<technique>.csv (per-round sim_clock + drop columns)\nexpected: schemes with smaller payloads clear the deadline more often and reach\nhigher accuracy at every budget; wasted bytes quantify the over-selection cost.\n",
    );

    // ---- rate-policy leg: the same wall-clock question on a longtail
    // fleet, comparing rate *policies* instead of techniques — DGCwGMF at
    // fixed rates 0.05/0.10/0.25 vs the per-client adaptive controller
    // seeded at 0.10. The target accuracy is the worst policy's final
    // accuracy (the budget every run provably reaches), and the headline
    // column is the uplink each policy spent to get there.
    use crate::compress::RateControlMode;
    let lt_sim = SimConfig {
        preset: ProfilePreset::LongTail { sigma: 1.0 },
        deadline_s: 0.2,
        dropout: 0.0,
        overselect: 1.25,
        compute_s: 0.08,
        staleness: StalenessPolicy::CarryDiscounted(0.5),
        ..Default::default()
    };
    let policies: [(&str, f64, bool); 4] = [
        ("fixed_0.05", 0.05, false),
        ("fixed_0.10", 0.10, false),
        ("fixed_0.25", 0.25, false),
        ("adaptive", 0.10, true),
    ];
    let mut rc_rows: Vec<(&str, f64, RunSummary)> = Vec::new();
    for &(name, rate, adaptive) in &policies {
        let mut cfg = args.base_cfg(Task::Cifar);
        cfg.technique = CompressorKind::DgcWgmf;
        cfg.emd = 1.35;
        cfg.client_fraction = 0.75;
        cfg.eval_every = (cfg.rounds / 10).max(1);
        cfg.rate = rate;
        cfg.sim = lt_sim;
        if adaptive {
            cfg.rate_control.mode = RateControlMode::Adaptive;
            cfg.rate_control.max_rate_boost = 1.5;
        }
        let (s, _) = execute(&cfg, &args.artifacts, &mut ctx)?;
        write_curve(&s, &dir, &format!("rate_{name}"))?;
        eprintln!(
            "[time_to_accuracy] rate policy {name}: acc={:.4} uplink={:.4} GB late={}",
            s.final_accuracy, s.uplink_gb, s.dropped_deadline
        );
        rc_rows.push((name, rate, s));
    }
    let target_acc =
        rc_rows.iter().map(|(_, _, s)| s.final_accuracy).fold(f64::INFINITY, f64::min);
    let mut rc_csv = String::from(
        "policy,base_rate,final_accuracy,target_accuracy,uplink_gb_to_target,total_uplink_gb,late,coding_downshifts,rate_mean_last\n",
    );
    let _ = writeln!(
        out,
        "\nRate policies — longtail fleet (sigma 1.0), DGCwGMF, target acc {target_acc:.4}\n\
         {:<11} {:>5} {:>9} {:>14} {:>11} {:>6} {:>10}",
        "Policy", "rate", "accuracy", "up@target(GB)", "uplink(GB)", "late", "downshifts"
    );
    for (name, rate, s) in &rc_rows {
        let mut up_bytes = 0usize;
        let mut up_to_target: Option<f64> = None;
        let mut best = 0.0f64;
        let mut downshifts = 0usize;
        for r in &s.recorder.rounds {
            up_bytes += r.uplink_bytes;
            downshifts += r.coding_downshifts;
            best = best.max(r.test_accuracy);
            if up_to_target.is_none() && best >= target_acc {
                up_to_target = Some(up_bytes as f64 / 1e9);
            }
        }
        let to_target = up_to_target.unwrap_or(s.uplink_gb);
        let rate_last = s.recorder.rounds.last().map(|r| r.rate_mean).unwrap_or(*rate);
        let _ = writeln!(
            out,
            "{:<11} {:>5.2} {:>9.4} {:>14.4} {:>11.4} {:>6} {:>10}",
            name, rate, s.final_accuracy, to_target, s.uplink_gb, s.dropped_deadline, downshifts
        );
        let _ = writeln!(
            rc_csv,
            "{name},{rate},{:.6},{target_acc:.6},{to_target:.6},{:.6},{},{downshifts},{rate_last:.6}",
            s.final_accuracy, s.uplink_gb, s.dropped_deadline
        );
    }
    std::fs::write(dir.join("rate_policies.csv"), rc_csv)?;
    out.push_str(
        "\nexpected: the adaptive policy reaches the target accuracy on less total uplink\nthan every fixed rate — tail clients ship floor-rate q8 uploads that make the\ndeadline instead of full ones that miss it.\nrate-policy table: results/time_to_accuracy/rate_policies.csv\n",
    );
    Ok(out)
}

// -------------------------------------------------------- staleness_sweep

/// Semi-synchronous aggregation study: the same longtail straggler fleet
/// under each staleness policy. `drop` wastes every straggler upload (the
/// bytes crossed the wire, the server discarded them — the waste
/// `time_to_accuracy` measures), `carry` folds late uploads into the next
/// round at full weight (wasted straggler bytes ≈ 0 by construction), and
/// `carry_discounted(α)` applies α of the late update server-side while
/// the client residual keeps 1 − α. A fourth variant pairs `carry` with
/// feasibility-aware selection (β = 0.5) to show the selection/fairness
/// interaction (`gini` column: spread of the per-client uplink bill).
/// `--levels` overrides α (first value); `--techniques` overrides the
/// default DGCwGMF.
fn staleness_sweep(args: &ExpArgs) -> Result<String> {
    let mut ctx: Option<Rc<PjrtContext>> = None;
    let dir = args.out_dir.join("staleness_sweep");
    let alpha = args.levels.first().copied().unwrap_or(0.5);
    let base_sim = SimConfig {
        preset: ProfilePreset::LongTail { sigma: 1.0 },
        deadline_s: 0.2,
        dropout: 0.0,
        overselect: 1.25,
        compute_s: 0.08,
        ..Default::default()
    };
    let variants: [(&str, StalenessPolicy, SelectionPolicy); 4] = [
        ("drop", StalenessPolicy::Drop, SelectionPolicy::Uniform),
        ("carry", StalenessPolicy::Carry, SelectionPolicy::Uniform),
        ("carry_disc", StalenessPolicy::CarryDiscounted(alpha), SelectionPolicy::Uniform),
        ("carry+feas", StalenessPolicy::Carry, SelectionPolicy::Feasibility { beta: 0.5 }),
    ];
    let techs = if args.techniques.is_empty() {
        vec![CompressorKind::DgcWgmf]
    } else {
        args.techs()
    };
    let mut out = format!(
        "Staleness sweep — longtail fleet (sigma 1.0) under a 0.2 s round deadline\n\
         (compute 0.08 s/step; 1.25x over-selection; carry_disc alpha = {alpha};\n\
         rate 0.1, EMD 1.35; wasted = straggler bytes the server discarded)\n\n"
    );
    let mut csv = String::from(
        "technique,policy,final_accuracy,late,offline,carried,wasted_gb,traffic_gb,gini\n",
    );
    let _ = writeln!(
        out,
        "{:<10} {:<11} {:>9} {:>6} {:>8} {:>8} {:>11} {:>12} {:>6}",
        "Technique", "Policy", "accuracy", "late", "offline", "carried", "wasted(GB)",
        "traffic(GB)", "gini"
    );
    for kind in techs {
        for &(name, staleness, selection) in &variants {
            let mut cfg = args.base_cfg(Task::Cifar);
            cfg.technique = kind;
            cfg.emd = 1.35;
            cfg.client_fraction = 0.75; // headroom for the over-selection
            cfg.eval_every = (cfg.rounds / 10).max(1);
            cfg.sim = SimConfig { staleness, selection, ..base_sim };
            let (s, _) = execute(&cfg, &args.artifacts, &mut ctx)?;
            let gini =
                s.recorder.rounds.last().map(|r| r.traffic_gini).unwrap_or(0.0);
            eprintln!(
                "[staleness_sweep] {} {}: acc={:.4} late={} carried={} wasted={:.4} GB",
                kind.name(),
                name,
                s.final_accuracy,
                s.dropped_deadline,
                s.carried_total,
                s.wasted_uplink_gb
            );
            write_curve(&s, &dir, &format!("{}_{name}", kind.name()))?;
            let _ = writeln!(
                out,
                "{:<10} {:<11} {:>9.4} {:>6} {:>8} {:>8} {:>11.4} {:>12.4} {:>6.3}",
                kind.name(),
                name,
                s.final_accuracy,
                s.dropped_deadline,
                s.dropped_offline,
                s.carried_total,
                s.wasted_uplink_gb,
                s.total_traffic_gb,
                gini
            );
            let _ = writeln!(
                csv,
                "{},{name},{:.6},{},{},{},{:.6},{:.6},{:.6}",
                kind.name(),
                s.final_accuracy,
                s.dropped_deadline,
                s.dropped_offline,
                s.carried_total,
                s.wasted_uplink_gb,
                s.total_traffic_gb,
                gini
            );
        }
    }
    std::fs::write(dir.join("sweep.csv"), csv)?;
    out.push_str(
        "\nexpected: identical late counts across policies at uniform selection; wasted\n\
         bytes ~ 0 under the carry policies (the same uploads land one round later as\n\
         `carried`); feasibility selection trades some cohort diversity (higher gini)\n\
         for fewer late uploads.\ncurves: results/staleness_sweep/<technique>_<policy>.csv\n",
    );
    Ok(out)
}

// ------------------------------------------------------- rate sweep shared

fn sweep_rates(args: &ExpArgs, task: Task, id: &str, title: &str) -> Result<String> {
    let rates: Vec<f64> =
        if args.levels.is_empty() { vec![0.1, 0.3, 0.5, 0.7, 0.9] } else { args.levels.clone() };
    let mut ctx: Option<Rc<PjrtContext>> = None;
    let mut out = format!("{title}\n\n");
    let mut csv = String::from("rate,technique,final_accuracy,traffic_gb,uplink_gb,downlink_gb\n");
    let _ = writeln!(
        out,
        "{:<7} {:<10} {:>10} {:>12} {:>10} {:>10}",
        "rate", "technique", "accuracy", "traffic(GB)", "up(GB)", "down(GB)"
    );
    for &rate in &rates {
        for kind in args.techs() {
            let mut cfg = args.base_cfg(task);
            cfg.technique = kind;
            cfg.rate = rate;
            if task == Task::Cifar {
                cfg.emd = 1.35;
            }
            let (s, _) = execute(&cfg, &args.artifacts, &mut ctx)?;
            eprintln!(
                "[{id}] rate={rate} {}: acc={:.4} traffic={:.4}",
                kind.name(),
                s.final_accuracy,
                s.total_traffic_gb
            );
            let _ = writeln!(
                out,
                "{:<7} {:<10} {:>10.4} {:>12.4} {:>10.4} {:>10.4}",
                rate, kind.name(), s.final_accuracy, s.total_traffic_gb, s.uplink_gb, s.downlink_gb
            );
            let _ = writeln!(
                csv,
                "{rate},{},{:.6},{:.6},{:.6},{:.6}",
                kind.name(), s.final_accuracy, s.total_traffic_gb, s.uplink_gb, s.downlink_gb
            );
        }
    }
    std::fs::write(args.out_dir.join(id).join("sweep.csv"), csv)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lists_all_paper_artifacts() {
        let l = list();
        for id in ["table1", "table2", "table3", "table4", "fig4", "fig5", "fig6"] {
            assert!(l.contains(id), "{id} missing");
        }
    }

    #[test]
    fn table2_matches_paper_matrix() {
        let t = table2();
        assert!(t.contains("DGCwGMF"));
        assert!(t.contains("v (in compression process)"));
        assert!(t.contains("v (in compensation process)"));
    }

    #[test]
    fn unknown_experiment_is_error() {
        let args = ExpArgs::new(PathBuf::from("artifacts"), std::env::temp_dir());
        assert!(run("nope", &args).is_err());
    }
}
