//! Experiment harness: one entry per paper table/figure (DESIGN.md §4).
//!
//! Each experiment builds its workloads, runs the comparison grid, prints
//! the same rows/series the paper reports, and writes CSV evidence under
//! `results/<id>/`. Absolute numbers differ from the paper (scaled-down
//! synthetic substrate — DESIGN.md §Substitutions); orderings and deltas
//! are the reproduction target.

pub mod registry;
pub mod runner;
pub mod workload;

pub use registry::{list, run, ExpArgs};
