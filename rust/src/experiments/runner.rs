//! Single-run driver shared by every experiment: config → workload →
//! engine → FL run → summary (+ optional CSV curve dump).

use super::workload::{build_engine, build_workload};
use crate::config::RunConfig;
use crate::coordinator::round::{FlRun, RunSummary};
use crate::runtime::pjrt::PjrtContext;
use crate::sim::network::Network;
use anyhow::Result;
use std::path::Path;
use std::rc::Rc;

/// Execute one configured FL run end-to-end.
pub fn execute(
    cfg: &RunConfig,
    artifacts: &Path,
    ctx: &mut Option<Rc<PjrtContext>>,
) -> Result<(RunSummary, f64)> {
    execute_with(cfg, artifacts, ctx, None)
}

/// [`execute`] with an optional simulated-seconds budget: when set, the run
/// stops as soon as the scheduler's round clock reaches the budget (still
/// capped at the configured round count) — the time-to-accuracy regime.
pub fn execute_with(
    cfg: &RunConfig,
    artifacts: &Path,
    ctx: &mut Option<Rc<PjrtContext>>,
    budget_s: Option<f64>,
) -> Result<(RunSummary, f64)> {
    cfg.validate()?;
    let workload = build_workload(cfg)?;
    let mut engine = build_engine(cfg, artifacts, ctx)?;
    let network = Network::uniform(cfg.clients, Default::default());
    let mut run = FlRun::new(
        engine.as_ref(),
        workload.shards,
        workload.test,
        network,
        cfg.fl_config(),
    );
    let summary = match budget_s {
        Some(b) => run.run_for_budget(engine.as_mut(), b)?,
        None => run.run(engine.as_mut())?,
    };
    Ok((summary, workload.achieved_emd))
}

/// Write a per-round CSV curve next to the experiment outputs.
pub fn write_curve(summary: &RunSummary, dir: &Path, name: &str) -> Result<()> {
    let path = dir.join(format!("{name}.csv"));
    summary.recorder.write_csv(&path)?;
    Ok(())
}

/// Render a paper-style comparison block: per technique, accuracy with delta
/// vs the DGC baseline and traffic with delta (the Tables 3/4 row format).
pub fn comparison_rows(rows: &[(String, RunSummary)]) -> String {
    let baseline = rows
        .iter()
        .find(|(name, _)| name == "DGC")
        .map(|(_, s)| (s.final_accuracy, s.total_traffic_gb));
    let mut out = String::new();
    out.push_str(&format!(
        "{:<10} {:>10} {:>9} {:>12} {:>9} {:>9}\n",
        "Technique", "Top1-Acc", "dAcc", "Traffic(GB)", "dGB", "overlap"
    ));
    for (name, s) in rows {
        let (dacc, dgb) = match baseline {
            Some((ba, bt)) if name != "DGC" => (
                format!("{:+.4}", s.final_accuracy - ba),
                format!("{:+.3}", s.total_traffic_gb - bt),
            ),
            _ => ("-".into(), "-".into()),
        };
        out.push_str(&format!(
            "{:<10} {:>10.4} {:>9} {:>12.4} {:>9} {:>9.3}\n",
            name, s.final_accuracy, dacc, s.total_traffic_gb, dgb, s.mean_mask_overlap
        ));
    }
    out
}
