//! Length-prefixed framing for the fedgmf service-mode wire protocol.
//!
//! Every frame on a service connection is `len: u32 LE | kind: u8 | body`,
//! where `len` counts the kind byte plus the body. The body of a model or
//! gradient frame is the self-describing sparse wire format
//! ([`crate::sparse::wire`], v1 and v2 both legal), so the transport layer
//! never interprets payload bytes — it only moves frames. Reads go through
//! `read_exact`, which loops over short reads, so a frame survives arbitrary
//! fragmentation (the proptests drive it one byte at a time); a stream that
//! ends mid-frame surfaces `UnexpectedEof`, never a partial message.

use std::io::{self, Read, Write};

/// Upper bound on a single frame's `len` field. Anything larger is treated
/// as a corrupt or adversarial stream and rejected before allocation.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Frame kind bytes.
pub const KIND_HELLO: u8 = 1;
pub const KIND_WELCOME: u8 = 2;
pub const KIND_ROUND: u8 = 3;
pub const KIND_UPLOAD: u8 = 4;
pub const KIND_DONE: u8 = 6;

/// Fate byte carried back to a client on its next `ROUND` (or `DONE`)
/// frame: the scheduler's verdict on that client's previous upload.
pub const FATE_NONE: u8 = 0xFF;
pub const FATE_ACCEPTED: u8 = 0;
pub const FATE_STRAGGLER: u8 = 1;
pub const FATE_OFFLINE: u8 = 2;

/// One service-protocol message.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// client -> server, first frame on every (re)connect
    Hello { client: u32 },
    /// server -> client, response to `Hello`
    Welcome { dim: u32, rounds: u32 },
    /// server -> client, opens a round: last round's broadcast payload
    /// (empty on round 0), whether this client is in the cohort, and the
    /// fate of the client's previous upload (`FATE_NONE` if it had none)
    Round { round: u32, participate: bool, fate: u8, payload: Vec<u8> },
    /// client -> server, the round's compressed gradient
    Upload { round: u32, client: u32, loss: f64, precodec: u64, payload: Vec<u8> },
    /// server -> client, run over; carries the final round's fate
    Done { fate: u8 },
}

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

impl Msg {
    pub fn kind(&self) -> u8 {
        match self {
            Msg::Hello { .. } => KIND_HELLO,
            Msg::Welcome { .. } => KIND_WELCOME,
            Msg::Round { .. } => KIND_ROUND,
            Msg::Upload { .. } => KIND_UPLOAD,
            Msg::Done { .. } => KIND_DONE,
        }
    }

    /// Append the complete frame (`len | kind | body`) to `out`.
    pub fn encode(&self, out: &mut Vec<u8>) {
        let start = out.len();
        out.extend_from_slice(&[0, 0, 0, 0]); // len backpatched below
        out.push(self.kind());
        match self {
            Msg::Hello { client } => out.extend_from_slice(&client.to_le_bytes()),
            Msg::Welcome { dim, rounds } => {
                out.extend_from_slice(&dim.to_le_bytes());
                out.extend_from_slice(&rounds.to_le_bytes());
            }
            Msg::Round { round, participate, fate, payload } => {
                out.extend_from_slice(&round.to_le_bytes());
                out.push(u8::from(*participate));
                out.push(*fate);
                out.extend_from_slice(payload);
            }
            Msg::Upload { round, client, loss, precodec, payload } => {
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&client.to_le_bytes());
                out.extend_from_slice(&loss.to_le_bytes());
                out.extend_from_slice(&precodec.to_le_bytes());
                out.extend_from_slice(payload);
            }
            Msg::Done { fate } => out.push(*fate),
        }
        let len = (out.len() - start - 4) as u32;
        out[start..start + 4].copy_from_slice(&len.to_le_bytes());
    }

    /// Parse a frame body (`kind` already split off the front).
    pub fn decode(kind: u8, body: &[u8]) -> io::Result<Msg> {
        fn u32_at(b: &[u8], at: usize) -> io::Result<u32> {
            let raw = b.get(at..at + 4).ok_or_else(|| bad("frame body truncated"))?;
            Ok(u32::from_le_bytes(raw.try_into().unwrap()))
        }
        fn u64_at(b: &[u8], at: usize) -> io::Result<u64> {
            let raw = b.get(at..at + 8).ok_or_else(|| bad("frame body truncated"))?;
            Ok(u64::from_le_bytes(raw.try_into().unwrap()))
        }
        match kind {
            KIND_HELLO => {
                if body.len() != 4 {
                    return Err(bad("HELLO body must be 4 bytes"));
                }
                Ok(Msg::Hello { client: u32_at(body, 0)? })
            }
            KIND_WELCOME => {
                if body.len() != 8 {
                    return Err(bad("WELCOME body must be 8 bytes"));
                }
                Ok(Msg::Welcome { dim: u32_at(body, 0)?, rounds: u32_at(body, 4)? })
            }
            KIND_ROUND => {
                if body.len() < 6 {
                    return Err(bad("ROUND body too short"));
                }
                let participate = match body[4] {
                    0 => false,
                    1 => true,
                    b => return Err(bad(format!("bad participate byte {b}"))),
                };
                Ok(Msg::Round {
                    round: u32_at(body, 0)?,
                    participate,
                    fate: body[5],
                    payload: body[6..].to_vec(),
                })
            }
            KIND_UPLOAD => {
                if body.len() < 24 {
                    return Err(bad("UPLOAD body too short"));
                }
                Ok(Msg::Upload {
                    round: u32_at(body, 0)?,
                    client: u32_at(body, 4)?,
                    loss: f64::from_le_bytes(body[8..16].try_into().unwrap()),
                    precodec: u64_at(body, 16)?,
                    payload: body[24..].to_vec(),
                })
            }
            KIND_DONE => {
                if body.len() != 1 {
                    return Err(bad("DONE body must be 1 byte"));
                }
                Ok(Msg::Done { fate: body[0] })
            }
            b => Err(bad(format!("unknown frame kind {b}"))),
        }
    }
}

/// Write one message as a single frame.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg, scratch: &mut Vec<u8>) -> io::Result<()> {
    scratch.clear();
    msg.encode(scratch);
    w.write_all(scratch)
}

/// Read exactly one frame. Loops over short reads (fragmentation-safe);
/// a stream ending anywhere inside the frame yields `UnexpectedEof`, a
/// length field over [`MAX_FRAME_BYTES`] or an unparseable body yields
/// `InvalidData`. No allocation happens before the length passes the bound
/// check.
pub fn read_msg<R: Read>(r: &mut R) -> io::Result<Msg> {
    let mut len_raw = [0u8; 4];
    r.read_exact(&mut len_raw)?;
    let len = u32::from_le_bytes(len_raw) as usize;
    if len == 0 {
        return Err(bad("empty frame"));
    }
    if len > MAX_FRAME_BYTES {
        return Err(bad(format!("frame length {len} exceeds cap {MAX_FRAME_BYTES}")));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Msg::decode(buf[0], &buf[1..])
}

/// Reassembly buffer for reading frames off a stream with read timeouts.
///
/// `read_exact` loses already-consumed bytes when a timeout fires
/// mid-frame, desynchronising the stream. Long-lived connections instead
/// feed raw reads into this buffer and pop complete frames; a timeout
/// between reads leaves partial frames intact.
#[derive(Debug, Default)]
pub struct FrameBuffer {
    buf: Vec<u8>,
}

impl FrameBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Discard buffered bytes (call when the underlying stream is replaced).
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// Feed freshly-read bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete frame, if one is fully buffered.
    pub fn next_msg(&mut self) -> io::Result<Option<Msg>> {
        if self.buf.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes(self.buf[..4].try_into().unwrap()) as usize;
        if len == 0 {
            return Err(bad("empty frame"));
        }
        if len > MAX_FRAME_BYTES {
            return Err(bad(format!("frame length {len} exceeds cap {MAX_FRAME_BYTES}")));
        }
        if self.buf.len() < 4 + len {
            return Ok(None);
        }
        let msg = Msg::decode(self.buf[4], &self.buf[5..4 + len])?;
        self.buf.drain(..4 + len);
        Ok(Some(msg))
    }
}

/// Read until one complete frame is available via `fb`. Timeouts
/// (`WouldBlock`/`TimedOut`) propagate to the caller with all buffered
/// bytes retained, so the next call resumes mid-frame cleanly.
pub fn read_msg_buffered<R: Read>(r: &mut R, fb: &mut FrameBuffer) -> io::Result<Msg> {
    loop {
        if let Some(m) = fb.next_msg()? {
            return Ok(m);
        }
        let mut tmp = [0u8; 8192];
        match r.read(&mut tmp)? {
            0 => return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "stream closed")),
            n => fb.extend(&tmp[..n]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_msgs() -> Vec<Msg> {
        vec![
            Msg::Hello { client: 7 },
            Msg::Welcome { dim: 16, rounds: 6 },
            Msg::Round { round: 3, participate: true, fate: FATE_STRAGGLER, payload: vec![9; 33] },
            Msg::Round { round: 0, participate: false, fate: FATE_NONE, payload: Vec::new() },
            Msg::Upload { round: 2, client: 4, loss: 0.625, precodec: 144, payload: vec![1, 2, 3] },
            Msg::Done { fate: FATE_ACCEPTED },
        ]
    }

    #[test]
    fn roundtrip_all_kinds() {
        let mut buf = Vec::new();
        let msgs = sample_msgs();
        for m in &msgs {
            write_msg(&mut buf, m, &mut Vec::new()).unwrap();
        }
        let mut r = &buf[..];
        for m in &msgs {
            assert_eq!(&read_msg(&mut r).unwrap(), m);
        }
        assert!(r.is_empty());
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.push(KIND_HELLO);
        let err = read_msg(&mut &buf[..]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn zero_length_rejected() {
        let buf = 0u32.to_le_bytes();
        assert_eq!(read_msg(&mut &buf[..]).unwrap_err().kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn unknown_kind_rejected() {
        let mut buf = Vec::new();
        Msg::Hello { client: 1 }.encode(&mut buf);
        buf[4] = 200; // kind byte
        assert_eq!(read_msg(&mut &buf[..]).unwrap_err().kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn frame_buffer_survives_byte_at_a_time_feeding() {
        let mut wire = Vec::new();
        let msgs = sample_msgs();
        for m in &msgs {
            m.encode(&mut wire);
        }
        let mut fb = FrameBuffer::new();
        let mut got = Vec::new();
        for &b in &wire {
            fb.extend(&[b]);
            while let Some(m) = fb.next_msg().unwrap() {
                got.push(m);
            }
        }
        assert_eq!(got, msgs);
        assert!(fb.next_msg().unwrap().is_none(), "buffer must be drained");
    }
}
