//! Transport abstraction for service mode: the round loop speaks this
//! trait exclusively and never knows whether its clients live in the same
//! process or behind loopback sockets.
//!
//! The contract is deliberately narrow — broadcast the round's model
//! payload down, collect the round's uploads back up, close at a
//! wall-clock deadline — because everything *semantic* (fates, staleness,
//! simulated time) stays in the coordinator, computed from arrival byte
//! counts by the same [`crate::sim::scheduler::Scheduler`] formulas the
//! in-process simulator uses. That is what makes the two backends
//! digest-identical: the transport moves bytes, the coordinator does math,
//! and the math never sees which transport ran.
//!
//! Chaos is layered in through [`fault::FaultPlan`], a stateless
//! per-(client, round) decision shared by both backends (and by the
//! coordinator, which must know e.g. which clients a `drop` plan silenced
//! so it can mark them offline instead of waiting out the wall deadline).

pub mod fault;
pub mod framing;
pub mod inproc;
pub mod socket;

use crate::transport::fault::FaultPlan;

/// One client upload as the transport delivers it: still encoded, plus the
/// sideband scalars the coordinator needs for bookkeeping.
#[derive(Clone, Debug)]
pub struct Upload {
    pub client: usize,
    /// round the client produced it in (may trail the current round — see
    /// [`RoundArrivals::late`])
    pub round: usize,
    /// the client's local training loss for the round
    pub loss: f64,
    /// pre-codec payload size, for codec-ratio accounting
    pub precodec_bytes: usize,
    /// the encoded gradient, exactly as the wire carried it
    pub bytes: Vec<u8>,
}

/// What one `collect` call produced.
#[derive(Debug, Default)]
pub struct RoundArrivals {
    /// current-round uploads, deduplicated, sorted by client id
    pub uploads: Vec<Upload>,
    /// genuinely-late frames from earlier rounds (socket stragglers in wall
    /// time); the coordinator routes these into the stale queue when the
    /// staleness policy carries
    pub late: Vec<Upload>,
}

/// Monotonic counters a backend accumulates over its lifetime. The
/// coordinator records per-round deltas; none of these enter the
/// trajectory digest (wall-clock retries are not simulation state).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// client reconnect/resend attempts observed (truncate/disconnect faults)
    pub retries: usize,
    /// expected uploads still missing when a round hit its wall deadline
    pub timeouts: usize,
    /// frames that arrived after their round had already closed
    pub stale_frames: usize,
    /// duplicate (client, round) frames rejected
    pub dup_frames: usize,
}

impl TransportStats {
    /// Counter-wise `self - earlier` (saturating, for per-round deltas).
    pub fn delta(&self, earlier: &TransportStats) -> TransportStats {
        TransportStats {
            retries: self.retries.saturating_sub(earlier.retries),
            timeouts: self.timeouts.saturating_sub(earlier.timeouts),
            stale_frames: self.stale_frames.saturating_sub(earlier.stale_frames),
            dup_frames: self.dup_frames.saturating_sub(earlier.dup_frames),
        }
    }
}

/// Server-side view of a client fleet.
pub trait Transport {
    /// Open `round`: deliver last round's broadcast payload to *every*
    /// client (cohort members get `participate = true`) along with each
    /// client's previous-upload fate byte.
    fn broadcast(
        &mut self,
        round: usize,
        payload: &[u8],
        cohort: &[usize],
        fates: &[u8],
    ) -> anyhow::Result<()>;

    /// Block until every expected upload arrived or `wall_deadline_ms`
    /// elapsed, then close the round with whoever made it. `expected` is
    /// the cohort minus clients the fault plan silenced (the caller knows
    /// the plan too and marks those offline itself).
    fn collect(
        &mut self,
        round: usize,
        expected: &[usize],
        wall_deadline_ms: u64,
    ) -> anyhow::Result<RoundArrivals>;

    /// End the run: tell every client its final fate and release resources.
    fn shutdown(&mut self, fates: &[u8]) -> anyhow::Result<()>;

    fn stats(&self) -> TransportStats;
}

/// Client-side round handler, implemented by
/// [`crate::coordinator::service::ServiceClient`]. The in-process backend
/// calls it directly; the socket client loop calls it between frames.
pub trait ClientHandler: Send {
    fn id(&self) -> usize;
    /// Handle one `ROUND` frame: apply the previous fate, mirror the model
    /// update, train if selected. Returns the upload to send, or `None`
    /// when not participating (or when a `drop` plan silenced this round).
    fn handle_round(
        &mut self,
        round: usize,
        payload: &[u8],
        participate: bool,
        fate: u8,
    ) -> anyhow::Result<Option<Upload>>;
    /// Handle the final `DONE` frame (applies the last round's fate).
    fn handle_done(&mut self, fate: u8) -> anyhow::Result<()>;
}

/// `[transport]` config block: socket addresses, timeouts, backoff and the
/// optional chaos plan. Defaults are loopback-friendly.
#[derive(Clone, Debug, PartialEq)]
pub struct TransportConfig {
    /// listen/connect address: `host:port` TCP, or `unix:/path` for a
    /// Unix-domain socket
    pub addr: String,
    /// per-connection read timeout (server reader threads poll at this)
    pub read_timeout_ms: u64,
    /// per-connection write timeout
    pub write_timeout_ms: u64,
    /// wall-clock deadline for closing a round with whoever arrived
    pub round_deadline_ms: u64,
    /// client-side reconnect/resend attempts per round before giving up
    pub max_retries: u32,
    /// exponential backoff base between reconnect attempts...
    pub backoff_base_ms: u64,
    /// ...bounded by this cap
    pub backoff_max_ms: u64,
    /// chaos plan applied by both backends (`kind:rate[@seed]`)
    pub fault: Option<FaultPlan>,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            addr: "127.0.0.1:7070".to_string(),
            read_timeout_ms: 2_000,
            write_timeout_ms: 2_000,
            round_deadline_ms: 30_000,
            max_retries: 6,
            backoff_base_ms: 25,
            backoff_max_ms: 1_000,
            fault: None,
        }
    }
}

impl TransportConfig {
    /// Backoff delay before reconnect attempt `attempt` (0-based):
    /// `base * 2^attempt`, capped.
    pub fn backoff_ms(&self, attempt: u32) -> u64 {
        let shifted = self.backoff_base_ms.saturating_mul(1u64 << attempt.min(20));
        shifted.min(self.backoff_max_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_bounded_exponential() {
        let cfg = TransportConfig { backoff_base_ms: 25, backoff_max_ms: 200, ..Default::default() };
        assert_eq!(cfg.backoff_ms(0), 25);
        assert_eq!(cfg.backoff_ms(1), 50);
        assert_eq!(cfg.backoff_ms(2), 100);
        assert_eq!(cfg.backoff_ms(3), 200);
        assert_eq!(cfg.backoff_ms(10), 200, "cap must hold");
        assert_eq!(cfg.backoff_ms(63), 200, "shift must not overflow");
    }

    #[test]
    fn stats_delta_is_counterwise() {
        let a = TransportStats { retries: 5, timeouts: 1, stale_frames: 2, dup_frames: 3 };
        let b = TransportStats { retries: 2, timeouts: 1, stale_frames: 0, dup_frames: 1 };
        assert_eq!(
            a.delta(&b),
            TransportStats { retries: 3, timeouts: 0, stale_frames: 2, dup_frames: 2 }
        );
    }
}
