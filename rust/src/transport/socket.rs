//! Socket transport backend: `fedgmf serve` / `fedgmf client` over TCP or
//! Unix-domain sockets.
//!
//! Layout: one acceptor thread turns connections into per-connection
//! reader threads after the `HELLO`/`WELCOME` handshake; every reader
//! funnels frames into a single mpsc channel, so the server's round loop
//! stays single-threaded and processes events in arrival order. Writers
//! are cloned stream handles owned by the round loop.
//!
//! Robustness contract:
//! - per-connection read/write timeouts (`[transport]` config), with a
//!   reassembly buffer so a timeout mid-frame never desynchronises the
//!   stream;
//! - the client reconnects with bounded exponential backoff and resends
//!   its upload — at-least-once delivery, which the server turns into
//!   exactly-once via (client, round) dedup;
//! - a round closes at its wall deadline with whoever arrived; expected
//!   uploads still missing count as `timeouts` and the coordinator marks
//!   them offline (graceful degradation);
//! - frames for already-closed rounds count as `stale_frames` and are
//!   handed back as [`RoundArrivals::late`] for the stale queue.
//!
//! Chaos: the client applies its fault plan on the send path (drop is
//! handled in the handler; delay sleeps; duplicate double-sends; truncate
//! cuts the frame mid-body and drops the connection; disconnect drops it
//! just before sending). Reorder needs no socket-side action — arrival
//! order across independent connections is already unordered, and the
//! coordinator sorts by client id.

use std::collections::{HashMap, HashSet};
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context};

use crate::transport::fault::FaultKind;
use crate::transport::framing::{self, FrameBuffer, Msg, FATE_NONE};
use crate::transport::{
    ClientHandler, RoundArrivals, Transport, TransportConfig, TransportStats, Upload,
};

/// Real milliseconds a `delay`-faulted client sleeps before sending. Small
/// on purpose: wall-clock delay exercises the server's wait loop, while
/// the *simulated* delay that can flip fates is [`super::fault::DELAY_S`]
/// applied in the coordinator.
const DELAY_SLEEP_MS: u64 = 20;

/// Acceptor poll interval while waiting for connections.
const ACCEPT_POLL_MS: u64 = 5;

// ---------------------------------------------------------------- streams

/// A connected stream of either address family.
pub enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    /// Connect to `addr` (`host:port`, or `unix:/path`).
    pub fn connect(addr: &str) -> io::Result<Conn> {
        match addr.strip_prefix("unix:") {
            #[cfg(unix)]
            Some(path) => Ok(Conn::Unix(UnixStream::connect(path)?)),
            #[cfg(not(unix))]
            Some(_) => Err(io::Error::new(io::ErrorKind::Unsupported, "unix sockets unavailable")),
            None => Ok(Conn::Tcp(TcpStream::connect(addr)?)),
        }
    }

    fn try_clone(&self) -> io::Result<Conn> {
        match self {
            Conn::Tcp(s) => Ok(Conn::Tcp(s.try_clone()?)),
            #[cfg(unix)]
            Conn::Unix(s) => Ok(Conn::Unix(s.try_clone()?)),
        }
    }

    fn set_timeouts(&self, read_ms: u64, write_ms: u64) -> io::Result<()> {
        let r = Some(Duration::from_millis(read_ms.max(1)));
        let w = Some(Duration::from_millis(write_ms.max(1)));
        match self {
            Conn::Tcp(s) => {
                s.set_nonblocking(false)?;
                s.set_read_timeout(r)?;
                s.set_write_timeout(w)
            }
            #[cfg(unix)]
            Conn::Unix(s) => {
                s.set_nonblocking(false)?;
                s.set_read_timeout(r)?;
                s.set_write_timeout(w)
            }
        }
    }

    fn shutdown(&self) {
        match self {
            Conn::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            #[cfg(unix)]
            Conn::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener, String),
}

impl Listener {
    fn bind(addr: &str) -> io::Result<Listener> {
        match addr.strip_prefix("unix:") {
            #[cfg(unix)]
            Some(path) => {
                let _ = std::fs::remove_file(path);
                Ok(Listener::Unix(UnixListener::bind(path)?, path.to_string()))
            }
            #[cfg(not(unix))]
            Some(_) => Err(io::Error::new(io::ErrorKind::Unsupported, "unix sockets unavailable")),
            None => Ok(Listener::Tcp(TcpListener::bind(addr)?)),
        }
    }

    fn set_nonblocking(&self) -> io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(true),
            #[cfg(unix)]
            Listener::Unix(l, _) => l.set_nonblocking(true),
        }
    }

    fn accept(&self) -> io::Result<Conn> {
        match self {
            Listener::Tcp(l) => Ok(Conn::Tcp(l.accept()?.0)),
            #[cfg(unix)]
            Listener::Unix(l, _) => Ok(Conn::Unix(l.accept()?.0)),
        }
    }

    /// The connectable address (resolves `:0` TCP ports).
    fn local_addr(&self) -> String {
        match self {
            Listener::Tcp(l) => l.local_addr().map(|a| a.to_string()).unwrap_or_default(),
            #[cfg(unix)]
            Listener::Unix(_, path) => format!("unix:{path}"),
        }
    }
}

// ----------------------------------------------------------------- server

enum Event {
    Joined { client: usize, writer: Conn },
    Up(Upload),
    Gone,
}

pub struct SocketTransport {
    n_clients: usize,
    cfg: TransportConfig,
    events: Receiver<Event>,
    writers: HashMap<usize, Conn>,
    /// clients that have joined at least once (a re-join is a retry)
    ever_joined: HashSet<usize>,
    /// (client, round) pairs already delivered to the coordinator
    delivered: HashSet<(usize, usize)>,
    /// current round's replay state for mid-round re-joins
    cur: Option<(usize, Vec<u8>, Vec<usize>, Vec<u8>)>,
    /// current-round uploads drained from the channel but not yet collected
    pending: Vec<Upload>,
    late: Vec<Upload>,
    stats: TransportStats,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    local_addr: String,
    scratch: Vec<u8>,
}

impl SocketTransport {
    /// Bind and start accepting. `dim`/`rounds` are echoed to clients in
    /// `WELCOME` so a misconfigured client fails fast instead of training
    /// on the wrong shapes.
    pub fn bind(
        cfg: TransportConfig,
        n_clients: usize,
        dim: usize,
        rounds: usize,
    ) -> anyhow::Result<SocketTransport> {
        let listener = Listener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
        listener.set_nonblocking().context("listener nonblocking")?;
        let local_addr = listener.local_addr();
        let (tx, rx) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let acceptor = {
            let stop = Arc::clone(&stop);
            let cfg = cfg.clone();
            std::thread::spawn(move || accept_loop(listener, cfg, dim, rounds, tx, stop))
        };
        Ok(SocketTransport {
            n_clients,
            cfg,
            events: rx,
            writers: HashMap::new(),
            ever_joined: HashSet::new(),
            delivered: HashSet::new(),
            cur: None,
            pending: Vec::new(),
            late: Vec::new(),
            stats: TransportStats::default(),
            stop,
            acceptor: Some(acceptor),
            local_addr,
            scratch: Vec::new(),
        })
    }

    /// The connectable address (use after binding `127.0.0.1:0`).
    pub fn local_addr(&self) -> &str {
        &self.local_addr
    }

    fn round_msg_for(&self, client: usize) -> Option<Msg> {
        let (round, payload, cohort, fates) = self.cur.as_ref()?;
        Some(Msg::Round {
            round: *round as u32,
            participate: cohort.binary_search(&client).is_ok(),
            fate: fates.get(client).copied().unwrap_or(FATE_NONE),
            payload: payload.clone(),
        })
    }

    fn send_to(&mut self, client: usize, msg: &Msg) -> bool {
        let mut scratch = std::mem::take(&mut self.scratch);
        let ok = match self.writers.get_mut(&client) {
            Some(w) => framing::write_msg(w, msg, &mut scratch).is_ok(),
            None => false,
        };
        self.scratch = scratch;
        ok
    }

    fn apply_event(&mut self, ev: Event) {
        match ev {
            Event::Joined { client, writer } => {
                if !self.ever_joined.insert(client) {
                    // reconnect after a fault or network hiccup
                    self.stats.retries += 1;
                }
                self.writers.insert(client, writer);
                // replay the current round so a client that missed its
                // ROUND frame mid-broadcast catches up (clients ignore
                // rounds they already handled)
                if let Some(msg) = self.round_msg_for(client) {
                    self.send_to(client, &msg);
                }
            }
            Event::Up(up) => {
                let cur_round = self.cur.as_ref().map(|c| c.0).unwrap_or(0);
                if self.delivered.contains(&(up.client, up.round)) {
                    self.stats.dup_frames += 1;
                } else if up.round < cur_round {
                    self.stats.stale_frames += 1;
                    self.delivered.insert((up.client, up.round));
                    self.late.push(up);
                } else {
                    self.delivered.insert((up.client, up.round));
                    self.pending.push(up);
                }
            }
            Event::Gone => {}
        }
    }

    fn drain_events(&mut self) {
        while let Ok(ev) = self.events.try_recv() {
            self.apply_event(ev);
        }
    }

    /// Wait until `pred(self)` holds or the deadline passes, applying
    /// events as they arrive. Returns whether the predicate held.
    fn wait_until(&mut self, deadline: Instant, pred: impl Fn(&SocketTransport) -> bool) -> bool {
        loop {
            self.drain_events();
            if pred(self) {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let step = (deadline - now).min(Duration::from_millis(20));
            match self.events.recv_timeout(step) {
                Ok(ev) => self.apply_event(ev),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => return pred(self),
            }
        }
    }
}

fn accept_loop(
    listener: Listener,
    cfg: TransportConfig,
    dim: usize,
    rounds: usize,
    tx: Sender<Event>,
    stop: Arc<AtomicBool>,
) {
    while !stop.load(Ordering::Relaxed) {
        let mut conn = match listener.accept() {
            Ok(c) => c,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(ACCEPT_POLL_MS));
                continue;
            }
            Err(_) => {
                std::thread::sleep(Duration::from_millis(ACCEPT_POLL_MS));
                continue;
            }
        };
        if conn.set_timeouts(cfg.read_timeout_ms, cfg.write_timeout_ms).is_err() {
            continue;
        }
        // handshake: HELLO up, WELCOME down. The buffer may already hold
        // bytes past HELLO (an eager resend) — it travels to the reader.
        let mut fb = FrameBuffer::new();
        let client = match framing::read_msg_buffered(&mut conn, &mut fb) {
            Ok(Msg::Hello { client }) => client as usize,
            _ => continue,
        };
        let welcome = Msg::Welcome { dim: dim as u32, rounds: rounds as u32 };
        if framing::write_msg(&mut conn, &welcome, &mut Vec::new()).is_err() {
            continue;
        }
        let writer = match conn.try_clone() {
            Ok(w) => w,
            Err(_) => continue,
        };
        if tx.send(Event::Joined { client, writer }).is_err() {
            return;
        }
        let tx = tx.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || reader_loop(conn, fb, tx, stop));
    }
}

fn reader_loop(mut conn: Conn, mut fb: FrameBuffer, tx: Sender<Event>, stop: Arc<AtomicBool>) {
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        match framing::read_msg_buffered(&mut conn, &mut fb) {
            Ok(Msg::Upload { round, client, loss, precodec, payload }) => {
                let up = Upload {
                    client: client as usize,
                    round: round as usize,
                    loss,
                    precodec_bytes: precodec as usize,
                    bytes: payload,
                };
                if tx.send(Event::Up(up)).is_err() {
                    return;
                }
            }
            Ok(_) => {}
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {}
            Err(_) => {
                // disconnect or mid-frame truncation: the partial frame is
                // discarded whole; the client will reconnect and resend
                let _ = tx.send(Event::Gone);
                return;
            }
        }
    }
}

impl Transport for SocketTransport {
    fn broadcast(
        &mut self,
        round: usize,
        payload: &[u8],
        cohort: &[usize],
        fates: &[u8],
    ) -> anyhow::Result<()> {
        self.cur = Some((round, payload.to_vec(), cohort.to_vec(), fates.to_vec()));
        // join barrier: every client must have connected at least once
        let deadline = Instant::now() + Duration::from_millis(self.cfg.round_deadline_ms);
        let n = self.n_clients;
        if !self.wait_until(deadline, |t| t.ever_joined.len() >= n) {
            bail!(
                "only {}/{} clients joined within {} ms",
                self.ever_joined.len(),
                self.n_clients,
                self.cfg.round_deadline_ms
            );
        }
        for client in 0..self.n_clients {
            let msg = self.round_msg_for(client).expect("cur round set above");
            if !self.send_to(client, &msg) {
                // writer is stale (client mid-reconnect): the Joined replay
                // in apply_event delivers this round when it returns
                self.writers.remove(&client);
            }
        }
        Ok(())
    }

    fn collect(
        &mut self,
        round: usize,
        expected: &[usize],
        wall_deadline_ms: u64,
    ) -> anyhow::Result<RoundArrivals> {
        let deadline = Instant::now() + Duration::from_millis(wall_deadline_ms);
        let want: HashSet<usize> = expected.iter().copied().collect();
        let have = |t: &SocketTransport| {
            let got: HashSet<usize> =
                t.pending.iter().filter(|u| u.round == round).map(|u| u.client).collect();
            want.iter().all(|c| got.contains(c))
        };
        if !self.wait_until(deadline, have) {
            let got: HashSet<usize> =
                self.pending.iter().filter(|u| u.round == round).map(|u| u.client).collect();
            self.stats.timeouts += want.iter().filter(|c| !got.contains(c)).count();
        }
        let mut out = RoundArrivals { uploads: Vec::new(), late: std::mem::take(&mut self.late) };
        for up in self.pending.drain(..) {
            debug_assert_eq!(up.round, round, "pending must only hold the open round");
            out.uploads.push(up);
        }
        out.uploads.sort_by_key(|u| u.client);
        Ok(out)
    }

    fn shutdown(&mut self, fates: &[u8]) -> anyhow::Result<()> {
        self.drain_events();
        let ids: Vec<usize> = self.writers.keys().copied().collect();
        for client in ids {
            let fate = fates.get(client).copied().unwrap_or(FATE_NONE);
            self.send_to(client, &Msg::Done { fate });
        }
        self.stop.store(true, Ordering::Relaxed);
        for w in self.writers.values() {
            w.shutdown();
        }
        self.writers.clear();
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        Ok(())
    }

    fn stats(&self) -> TransportStats {
        self.stats
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

// ----------------------------------------------------------------- client

struct ClientConn {
    conn: Conn,
    fb: FrameBuffer,
}

fn connect_handshake(cfg: &TransportConfig, id: usize) -> anyhow::Result<ClientConn> {
    let mut attempt = 0u32;
    loop {
        let tried = Conn::connect(&cfg.addr).and_then(|mut conn| {
            conn.set_timeouts(cfg.read_timeout_ms, cfg.write_timeout_ms)?;
            framing::write_msg(&mut conn, &Msg::Hello { client: id as u32 }, &mut Vec::new())?;
            let mut fb = FrameBuffer::new();
            match framing::read_msg_buffered(&mut conn, &mut fb)? {
                Msg::Welcome { .. } => Ok(ClientConn { conn, fb }),
                m => Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("expected WELCOME, got kind {}", m.kind()),
                )),
            }
        });
        match tried {
            Ok(cc) => return Ok(cc),
            Err(e) => {
                if attempt >= cfg.max_retries {
                    return Err(anyhow::Error::from(e)
                        .context(format!("client {id}: connect to {} failed", cfg.addr)));
                }
                std::thread::sleep(Duration::from_millis(cfg.backoff_ms(attempt)));
                attempt += 1;
            }
        }
    }
}

/// Send one upload frame, applying the fault plan's send-path mischief.
/// Reconnects (with backoff) and resends after a truncate/disconnect
/// fault, so delivery is at-least-once.
fn send_upload(cc: &mut ClientConn, cfg: &TransportConfig, up: &Upload) -> anyhow::Result<()> {
    let msg = Msg::Upload {
        round: up.round as u32,
        client: up.client as u32,
        loss: up.loss,
        precodec: up.precodec_bytes as u64,
        payload: up.bytes.clone(),
    };
    let mut frame = Vec::new();
    msg.encode(&mut frame);
    let fault = cfg.fault.filter(|p| p.hits(up.client, up.round)).map(|p| p.kind);
    match fault {
        Some(FaultKind::Delay) => {
            std::thread::sleep(Duration::from_millis(DELAY_SLEEP_MS));
            cc.conn.write_all(&frame)?;
        }
        Some(FaultKind::Duplicate) => {
            cc.conn.write_all(&frame)?;
            cc.conn.write_all(&frame)?;
        }
        Some(FaultKind::Truncate) => {
            // first attempt dies mid-frame; the server must discard the
            // partial frame whole
            let cut = frame.len() / 2;
            let _ = cc.conn.write_all(&frame[..cut]);
            let _ = cc.conn.flush();
            cc.conn.shutdown();
            *cc = connect_handshake(cfg, up.client)?;
            cc.conn.write_all(&frame)?;
        }
        Some(FaultKind::Disconnect) => {
            cc.conn.shutdown();
            *cc = connect_handshake(cfg, up.client)?;
            cc.conn.write_all(&frame)?;
        }
        // Drop never reaches here (the handler returns no upload);
        // Reorder is inherent to independent connections
        _ => cc.conn.write_all(&frame)?,
    }
    cc.conn.flush()?;
    Ok(())
}

/// The `fedgmf client` main loop: handshake, then handle `ROUND` frames
/// until `DONE`. Survives server-side silence up to
/// `max_retries * read_timeout_ms` and reconnects on connection loss.
pub fn run_client(cfg: &TransportConfig, handler: &mut dyn ClientHandler) -> anyhow::Result<()> {
    let id = handler.id();
    let mut cc = connect_handshake(cfg, id)?;
    let mut next_round = 0usize;
    let mut quiet = 0u32;
    loop {
        match framing::read_msg_buffered(&mut cc.conn, &mut cc.fb) {
            Ok(Msg::Round { round, participate, fate, payload }) => {
                quiet = 0;
                let r = round as usize;
                if r < next_round {
                    continue; // replay after a reconnect; already handled
                }
                next_round = r + 1;
                if let Some(up) = handler.handle_round(r, &payload, participate, fate)? {
                    send_upload(&mut cc, cfg, &up)?;
                }
            }
            Ok(Msg::Done { fate }) => {
                handler.handle_done(fate)?;
                return Ok(());
            }
            Ok(_) => {}
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                quiet += 1;
                if quiet > cfg.max_retries {
                    bail!("client {id}: server went quiet for {quiet} reads");
                }
            }
            Err(_) => {
                // connection lost between rounds: reconnect and wait for
                // the server's round replay
                quiet += 1;
                if quiet > cfg.max_retries {
                    bail!("client {id}: connection lost and retries exhausted");
                }
                cc = connect_handshake(cfg, id)?;
            }
        }
    }
}
