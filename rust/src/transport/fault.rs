//! Deterministic, seed-driven chaos injection for both transport backends.
//!
//! A [`FaultPlan`] is a pure function of `(seed, client, round)`: every
//! decision comes from one stateless SplitMix64 hash, so the in-process
//! backend, the socket backend, and every worker count see the *same*
//! faults for the same plan — the property the cross-backend digest test
//! and the cross-worker verify invariant both lean on. No generator state
//! is threaded anywhere; a backend asks `plan.hits(client, round)` at the
//! moment it needs the answer.
//!
//! The plan grammar is `kind:rate[@seed]`, e.g. `drop:0.25` or
//! `disconnect:0.4@7`. When `@seed` is omitted the run seed is used, so a
//! scenario string stays portable across fixtures.

use crate::util::rng::splitmix64;

/// Extra simulated seconds a `delay`-faulted upload takes to finish. Chosen
/// larger than the verify fixture's deadline slack so delayed uploads
/// genuinely flip to stragglers when a deadline is armed.
pub const DELAY_S: f64 = 0.05;

/// What the plan does to a hit upload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// upload never sent; the client restores its residual (offline)
    Drop,
    /// upload finishes [`DELAY_S`] later in simulated time
    Delay,
    /// the same frame arrives twice; the server must dedupe
    Duplicate,
    /// arrival order is scrambled; sorting by client id must normalise it
    Reorder,
    /// the frame is cut mid-body; the connection dies and the client resends
    Truncate,
    /// the connection drops before the frame; the client reconnects and resends
    Disconnect,
}

impl FaultKind {
    pub const ALL: [FaultKind; 6] = [
        FaultKind::Drop,
        FaultKind::Delay,
        FaultKind::Duplicate,
        FaultKind::Reorder,
        FaultKind::Truncate,
        FaultKind::Disconnect,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Drop => "drop",
            FaultKind::Delay => "delay",
            FaultKind::Duplicate => "dup",
            FaultKind::Reorder => "reorder",
            FaultKind::Truncate => "truncate",
            FaultKind::Disconnect => "disconnect",
        }
    }

    pub fn parse(s: &str) -> Option<FaultKind> {
        FaultKind::ALL.iter().copied().find(|k| k.name() == s)
    }
}

/// A seeded chaos scenario: `kind` applied at `rate` to each
/// (client, round) independently.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    pub kind: FaultKind,
    /// per-(client, round) hit probability in [0, 1]
    pub rate: f64,
    pub seed: u64,
}

impl FaultPlan {
    pub fn new(kind: FaultKind, rate: f64, seed: u64) -> Self {
        FaultPlan { kind, rate, seed }
    }

    /// Parse `kind:rate[@seed]`. `default_seed` fills in when `@seed` is
    /// absent.
    pub fn parse(s: &str, default_seed: u64) -> Result<FaultPlan, String> {
        let (kind_s, rest) =
            s.split_once(':').ok_or_else(|| format!("fault plan `{s}`: expected kind:rate"))?;
        let kind = FaultKind::parse(kind_s)
            .ok_or_else(|| format!("fault plan `{s}`: unknown kind `{kind_s}`"))?;
        let (rate_s, seed) = match rest.split_once('@') {
            Some((r, sd)) => {
                let seed = sd
                    .parse::<u64>()
                    .map_err(|_| format!("fault plan `{s}`: bad seed `{sd}`"))?;
                (r, seed)
            }
            None => (rest, default_seed),
        };
        let rate = rate_s
            .parse::<f64>()
            .map_err(|_| format!("fault plan `{s}`: bad rate `{rate_s}`"))?;
        if !(0.0..=1.0).contains(&rate) {
            return Err(format!("fault plan `{s}`: rate must be in [0, 1]"));
        }
        Ok(FaultPlan { kind, rate, seed })
    }

    /// The canonical string form (`kind:rate@seed`), re-parseable.
    pub fn describe(&self) -> String {
        format!("{}:{}@{}", self.kind.name(), self.rate, self.seed)
    }

    /// Stateless per-(client, round) decision. Identical on every backend,
    /// process and thread — no generator state exists to drift.
    pub fn hits(&self, client: usize, round: usize) -> bool {
        if self.rate <= 0.0 {
            return false;
        }
        if self.rate >= 1.0 {
            return true;
        }
        let mut h = self
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((client as u64) << 32)
            .wrapping_add(round as u64);
        let u = (splitmix64(&mut h) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < self.rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_is_pure_and_seed_sensitive() {
        let a = FaultPlan::new(FaultKind::Drop, 0.5, 42);
        let b = FaultPlan::new(FaultKind::Drop, 0.5, 42);
        let c = FaultPlan::new(FaultKind::Drop, 0.5, 43);
        let pat = |p: &FaultPlan| {
            (0..20).flat_map(|c| (0..20).map(move |r| (c, r))).map(|(c, r)| p.hits(c, r)).collect::<Vec<_>>()
        };
        assert_eq!(pat(&a), pat(&b), "same plan must be bit-identical");
        assert_ne!(pat(&a), pat(&c), "seed must matter");
    }

    #[test]
    fn rate_extremes() {
        let never = FaultPlan::new(FaultKind::Drop, 0.0, 1);
        let always = FaultPlan::new(FaultKind::Drop, 1.0, 1);
        for c in 0..10 {
            for r in 0..10 {
                assert!(!never.hits(c, r));
                assert!(always.hits(c, r));
            }
        }
    }

    #[test]
    fn rate_roughly_respected() {
        let p = FaultPlan::new(FaultKind::Delay, 0.25, 9);
        let n = 40_000;
        let hits = (0..200)
            .flat_map(|c| (0..200).map(move |r| (c, r)))
            .filter(|&(c, r)| p.hits(c, r))
            .count();
        let frac = hits as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "hit rate {frac} too far from 0.25");
    }

    #[test]
    fn parse_grammar() {
        let p = FaultPlan::parse("drop:0.25", 42).unwrap();
        assert_eq!(p, FaultPlan::new(FaultKind::Drop, 0.25, 42));
        let q = FaultPlan::parse("disconnect:0.4@7", 42).unwrap();
        assert_eq!(q, FaultPlan::new(FaultKind::Disconnect, 0.4, 7));
        assert_eq!(FaultPlan::parse(&q.describe(), 0).unwrap(), q);
        assert!(FaultPlan::parse("drop", 0).is_err());
        assert!(FaultPlan::parse("jitter:0.5", 0).is_err());
        assert!(FaultPlan::parse("drop:1.5", 0).is_err());
        assert!(FaultPlan::parse("drop:x", 0).is_err());
        assert!(FaultPlan::parse("drop:0.5@zz", 0).is_err());
    }
}
