//! In-process transport backend: the original simulator, now one backend
//! behind the [`Transport`] trait.
//!
//! Clients are owned [`ClientHandler`]s invoked synchronously during
//! `broadcast`; `collect` then replays the fault plan's frame-level
//! mischief (duplicates, reordering, retried truncations) on the buffered
//! uploads before handing the round to the coordinator. Because drop
//! decisions live in the client handler and delay decisions live in the
//! coordinator's scheduler math, the frame-level faults here are exactly
//! the ones that must be *invisible* after dedup + sort — which is what
//! the cross-backend digest test pins.

use crate::transport::fault::FaultKind;
use crate::transport::{ClientHandler, RoundArrivals, Transport, TransportConfig, TransportStats, Upload};

pub struct InProcTransport {
    clients: Vec<Box<dyn ClientHandler>>,
    cfg: TransportConfig,
    pending: Vec<Upload>,
    stats: TransportStats,
}

impl InProcTransport {
    /// `clients` must be sorted by [`ClientHandler::id`] and cover every
    /// client id the coordinator will put in a cohort.
    pub fn new(clients: Vec<Box<dyn ClientHandler>>, cfg: TransportConfig) -> Self {
        debug_assert!(clients.windows(2).all(|w| w[0].id() < w[1].id()));
        InProcTransport { clients, cfg, pending: Vec::new(), stats: TransportStats::default() }
    }
}

impl Transport for InProcTransport {
    fn broadcast(
        &mut self,
        round: usize,
        payload: &[u8],
        cohort: &[usize],
        fates: &[u8],
    ) -> anyhow::Result<()> {
        self.pending.clear();
        for c in self.clients.iter_mut() {
            let id = c.id();
            let participate = cohort.binary_search(&id).is_ok();
            let fate = fates.get(id).copied().unwrap_or(crate::transport::framing::FATE_NONE);
            if let Some(up) = c.handle_round(round, payload, participate, fate)? {
                if let Some(plan) = self.cfg.fault {
                    if plan.hits(id, round) {
                        match plan.kind {
                            // frame sent twice; collect() dedupes the copy
                            FaultKind::Duplicate => self.pending.push(up.clone()),
                            // first attempt dies mid-frame / mid-connection;
                            // the retry below delivers the same frame once
                            FaultKind::Truncate | FaultKind::Disconnect => {
                                self.stats.retries += 1;
                            }
                            // Drop is handled inside the client (it never
                            // returns an upload); Delay is scheduler math
                            FaultKind::Drop | FaultKind::Delay | FaultKind::Reorder => {}
                        }
                    }
                }
                self.pending.push(up);
            }
        }
        if matches!(self.cfg.fault, Some(p) if p.kind == FaultKind::Reorder) {
            // scramble arrival order; the sort in collect() must normalise it
            self.pending.reverse();
        }
        Ok(())
    }

    fn collect(
        &mut self,
        _round: usize,
        _expected: &[usize],
        _wall_deadline_ms: u64,
    ) -> anyhow::Result<RoundArrivals> {
        let mut out = RoundArrivals::default();
        let mut seen: Vec<usize> = Vec::new();
        for up in self.pending.drain(..) {
            if seen.contains(&up.client) {
                self.stats.dup_frames += 1;
                continue;
            }
            seen.push(up.client);
            out.uploads.push(up);
        }
        out.uploads.sort_by_key(|u| u.client);
        Ok(out)
    }

    fn shutdown(&mut self, fates: &[u8]) -> anyhow::Result<()> {
        for c in self.clients.iter_mut() {
            let fate = fates.get(c.id()).copied().unwrap_or(crate::transport::framing::FATE_NONE);
            c.handle_done(fate)?;
        }
        Ok(())
    }

    fn stats(&self) -> TransportStats {
        self.stats
    }
}
