//! Server-side buffering of deadline-missed uploads — the semi-synchronous
//! half of the time-domain scheduler.
//!
//! Under [`crate::sim::scheduler::StalenessPolicy::Drop`] a straggler's
//! upload is pure waste: the bytes crossed the wire and the server threw
//! them away. The carry policies route those uploads through this queue
//! instead: a late upload is copied into a pooled buffer when its round
//! closes, sits out exactly one round boundary, and is folded into the
//! *next* round's aggregate with the policy's staleness discount (see
//! `FlRun::step_round`). The queue is two-phase — `incoming` collects this
//! round's stragglers while `ready` holds last round's, and
//! [`StaleQueue::begin_round`] rotates them — so an upload can never enter
//! the same aggregate it missed.
//!
//! Buffers are pooled and reused: once capacities are warm, pushing and
//! recycling entries performs no heap allocation, preserving the round
//! loop's steady-state allocation-free property.

use crate::sparse::vector::SparseVec;

/// One buffered late upload.
#[derive(Clone, Debug)]
pub struct StaleEntry {
    /// client that produced the upload
    pub client: usize,
    /// round the upload was produced in (its age is visible to diagnostics)
    pub round: usize,
    /// wire bytes the upload cost — already metered as uplink when it
    /// arrived; carried here so the recorder can attribute carried bytes
    pub bytes: usize,
    /// the decoded gradient, exactly as the server would have aggregated it
    pub grad: SparseVec,
}

/// Two-phase queue of late uploads awaiting the next round's aggregate.
#[derive(Debug, Default)]
pub struct StaleQueue {
    /// last round's stragglers: folded into the current round's aggregate
    ready: Vec<StaleEntry>,
    /// this round's stragglers: become `ready` at the next `begin_round`
    incoming: Vec<StaleEntry>,
    /// recycled gradient buffers (capacity kept)
    pool: Vec<SparseVec>,
}

impl StaleQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Buffer a late upload for the next round. The gradient is copied into
    /// a pooled buffer — no steady-state allocation once capacities are
    /// warm.
    ///
    /// Idempotent per `(client, round)`: a second push for a pair already
    /// buffered (either phase) is rejected and returns `false`. Without
    /// this, a client present in both the current cohort and a transport's
    /// late-frame path could be queued twice for one upload — and since the
    /// coordinator pairs exactly one client-side residual restore with each
    /// successful push, the duplicate would inject gradient mass that was
    /// never transmitted.
    pub fn push(&mut self, client: usize, round: usize, bytes: usize, grad: &SparseVec) -> bool {
        if self
            .ready
            .iter()
            .chain(self.incoming.iter())
            .any(|e| e.client == client && e.round == round)
        {
            return false;
        }
        let mut buf = self.pool.pop().unwrap_or_else(|| SparseVec::empty(0));
        buf.dim = grad.dim;
        buf.indices.clear();
        buf.indices.extend_from_slice(&grad.indices);
        buf.values.clear();
        buf.values.extend_from_slice(&grad.values);
        self.incoming.push(StaleEntry { client, round, bytes, grad: buf });
        true
    }

    /// Rotate the phases: what arrived late last round becomes available
    /// for this round's aggregate. Call exactly once per round, before any
    /// `push`, after the previous round's `recycle_ready`.
    pub fn begin_round(&mut self) {
        debug_assert!(self.ready.is_empty(), "recycle_ready before the next begin_round");
        std::mem::swap(&mut self.ready, &mut self.incoming);
    }

    /// Late uploads to fold into the current round's aggregate.
    pub fn ready(&self) -> &[StaleEntry] {
        &self.ready
    }

    /// Return the applied entries' buffers to the pool.
    pub fn recycle_ready(&mut self) {
        for e in self.ready.drain(..) {
            self.pool.push(e.grad);
        }
    }

    /// Uploads buffered but not yet folded into any aggregate (both
    /// phases). Nonzero at the end of a run means the run closed holding
    /// paid-for updates that never reached an aggregate.
    pub fn pending(&self) -> usize {
        self.ready.len() + self.incoming.len()
    }

    /// All buffered entries, `ready` first — used by the conservation tests
    /// to account for mass the run ended holding.
    pub fn pending_entries(&self) -> impl Iterator<Item = &StaleEntry> + '_ {
        self.ready.iter().chain(self.incoming.iter())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(dim: usize, pairs: Vec<(u32, f32)>) -> SparseVec {
        SparseVec::new(dim, pairs)
    }

    #[test]
    fn entries_sit_out_exactly_one_round_boundary() {
        let mut q = StaleQueue::new();
        q.begin_round(); // round 0 opens: nothing ready
        assert!(q.ready().is_empty());
        q.push(3, 0, 120, &sv(8, vec![(1, 2.0), (5, -1.0)]));
        assert!(q.ready().is_empty(), "a push must not enter the current round");
        assert_eq!(q.pending(), 1);
        q.recycle_ready();

        q.begin_round(); // round 1 opens: round 0's straggler is ready
        assert_eq!(q.ready().len(), 1);
        assert_eq!(q.ready()[0].client, 3);
        assert_eq!(q.ready()[0].round, 0);
        assert_eq!(q.ready()[0].bytes, 120);
        assert_eq!(q.ready()[0].grad.indices, vec![1, 5]);
        q.push(4, 1, 90, &sv(8, vec![(2, 1.0)]));
        assert_eq!(q.pending(), 2);
        q.recycle_ready();
        assert_eq!(q.pending(), 1);

        q.begin_round(); // round 2: only round 1's straggler remains
        assert_eq!(q.ready().len(), 1);
        assert_eq!(q.ready()[0].client, 4);
        q.recycle_ready();
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn buffers_are_pooled_and_reused() {
        let mut q = StaleQueue::new();
        q.begin_round();
        q.push(0, 0, 10, &sv(16, vec![(0, 1.0), (9, 2.0)]));
        q.recycle_ready();
        q.begin_round();
        let ptr = q.ready()[0].grad.indices.as_ptr();
        q.recycle_ready();
        q.begin_round();
        // same-or-smaller payload must reuse the recycled buffer
        q.push(1, 2, 10, &sv(16, vec![(3, 4.0)]));
        q.recycle_ready();
        q.begin_round();
        assert_eq!(q.ready()[0].grad.indices.as_ptr(), ptr, "pool must recycle buffers");
        assert_eq!(q.ready()[0].grad.indices, vec![3]);
        assert_eq!(q.ready()[0].grad.values, vec![4.0]);
        q.recycle_ready();
    }

    #[test]
    fn push_is_idempotent_per_client_round() {
        // regression: one upload must yield at most one queued entry, no
        // matter how many paths (round loop + late transport frames) try
        // to buffer it — in either phase of the queue
        let mut q = StaleQueue::new();
        q.begin_round();
        assert!(q.push(3, 0, 120, &sv(8, vec![(1, 2.0)])));
        assert!(!q.push(3, 0, 120, &sv(8, vec![(1, 2.0)])), "dup in incoming");
        assert_eq!(q.pending(), 1);
        q.recycle_ready();
        q.begin_round(); // the entry is now in `ready`
        assert!(!q.push(3, 0, 120, &sv(8, vec![(1, 2.0)])), "dup in ready");
        assert_eq!(q.pending(), 1);
        // a different round from the same client is a distinct upload
        assert!(q.push(3, 1, 120, &sv(8, vec![(1, 2.0)])));
        assert_eq!(q.pending(), 2);
        let mass: f64 =
            q.pending_entries().flat_map(|e| e.grad.values.iter()).map(|&v| v as f64).sum();
        assert_eq!(mass, 4.0, "exactly two entries' worth of mass buffered");
        q.recycle_ready();
    }

    #[test]
    fn pending_entries_cover_both_phases() {
        let mut q = StaleQueue::new();
        q.begin_round();
        q.push(0, 0, 5, &sv(4, vec![(1, 1.0)]));
        q.begin_round();
        q.push(1, 1, 6, &sv(4, vec![(2, 2.0)]));
        let clients: Vec<usize> = q.pending_entries().map(|e| e.client).collect();
        assert_eq!(clients, vec![0, 1], "ready first, then incoming");
        let mass: f64 =
            q.pending_entries().flat_map(|e| e.grad.values.iter()).map(|&v| v as f64).sum();
        assert_eq!(mass, 3.0);
    }
}
