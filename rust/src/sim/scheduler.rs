//! Time-domain round scheduler: stragglers, dropouts, deadlines.
//!
//! PR 1 left the network simulator as a passive per-round time estimator —
//! bytes went in, seconds came out, and no decision ever depended on the
//! clock. This module promotes it into an active subsystem: every client
//! gets a capability profile (link spec + a compute-speed multiplier), the
//! sampler over-provisions the cohort, each selected client's simulated
//! finish time is `compute_time + uplink_time`, and the server applies a
//! deadline — uploads that arrive late (or never, for hard dropouts) are
//! discarded from the aggregate while the client's accumulated gradient
//! residual is retained, so DGC/GMF error-feedback semantics survive the
//! drop (see [`crate::compress::Compressor::restore_upload`]).
//!
//! ## Determinism contract
//!
//! With the default [`SimConfig`] (no deadline, no dropout, no
//! over-selection, no compute model, uniform profiles) every code path here
//! reduces to the PR 1 passive estimator *bit-exactly*: finish times are
//! `0.0 + latency + bytes/up_bps`, every fate is `Accepted`, and the
//! uplink-phase duration is the same `fold(0.0, f64::max)` the old
//! `Network::uplink_time` computed. `tests/determinism.rs` pins this.
//! Dropout draws come from a per-round RNG derived from the run seed, in
//! participant order, so scheduled runs are also bit-identical at any
//! worker count.

use super::network::{LinkSpec, Network};
use crate::util::rng::Rng;

/// How per-client capability profiles are generated from the base network.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ProfilePreset {
    /// Every client keeps its base link and unit compute speed.
    Uniform,
    /// Every `slow_every`-th client is `slow_factor`× slower: link bandwidth
    /// divided and compute time multiplied (a bimodal fleet — e.g. phones on
    /// Wi-Fi vs phones on congested cellular).
    Heterogeneous { slow_every: usize, slow_factor: f64 },
    /// Log-normal long tail: client slowdown `exp(sigma · |N(0,1)|)` ≥ 1,
    /// applied to both link and compute — most clients near 1×, a heavy
    /// tail of very slow devices (the empirical FL fleet shape).
    LongTail { sigma: f64 },
}

impl ProfilePreset {
    pub fn name(&self) -> &'static str {
        match self {
            ProfilePreset::Uniform => "uniform",
            ProfilePreset::Heterogeneous { .. } => "heterogeneous",
            ProfilePreset::LongTail { .. } => "longtail",
        }
    }
}

/// What the server does with an upload that crossed the wire but missed the
/// round deadline — the semi-synchronous aggregation policy.
///
/// Under `Drop` the bytes are wasted: the client paid the uplink and the
/// server discards the update (its residual is restored client-side). The
/// carry policies instead buffer the late upload in the server's
/// [`crate::sim::staleness::StaleQueue`] and fold it into the *next*
/// round's aggregate, so paid-for uplink traffic is never thrown away.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StalenessPolicy {
    /// Discard late uploads; restore the client residual (the default, and
    /// exactly the pre-semi-sync scheduler behaviour).
    Drop,
    /// Fold late uploads into the next round's aggregate at full weight.
    /// Equivalent to `CarryDiscounted(1.0)`.
    Carry,
    /// Fold late uploads in with staleness discount `alpha` in [0, 1]; the
    /// remaining `1 − alpha` of the upload is restored into the client
    /// residual, so no gradient mass is ever lost. `alpha = 0` degenerates
    /// to `Drop` exactly (byte-identical, by construction); `alpha = 1` is
    /// `Carry`.
    CarryDiscounted(f64),
}

impl StalenessPolicy {
    /// Weight applied to carried uploads when they enter the next round's
    /// aggregate.
    pub fn alpha(&self) -> f32 {
        match self {
            StalenessPolicy::Drop => 0.0,
            StalenessPolicy::Carry => 1.0,
            StalenessPolicy::CarryDiscounted(a) => *a as f32,
        }
    }

    /// Whether late uploads are buffered at all (α > 0). A zero discount
    /// carries nothing, which is what makes `carry_discounted(0)` take the
    /// `Drop` code path bit-for-bit.
    pub fn carries(&self) -> bool {
        self.alpha() > 0.0
    }

    pub fn name(&self) -> &'static str {
        match self {
            StalenessPolicy::Drop => "drop",
            StalenessPolicy::Carry => "carry",
            StalenessPolicy::CarryDiscounted(_) => "carry_discounted",
        }
    }
}

/// How the sampler picks *which* clients fill the round's cohort.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SelectionPolicy {
    /// Uniform random cohort (the default; exactly the pre-semi-sync
    /// shuffle-and-truncate draw).
    Uniform,
    /// Scheduler-aware selection: weight each client by
    /// `(1 − β) + β · hit_rate · traffic_parity`, where `hit_rate` is its
    /// Laplace-smoothed deadline-delivery history and `traffic_parity`
    /// de-prioritises clients that already spent more uplink bytes than
    /// the fleet average (see
    /// [`crate::coordinator::sampler::feasibility_weights`]). The `1 − β`
    /// term is the fairness floor: every client keeps a strictly positive
    /// selection weight at any β in [0, 1].
    Feasibility { beta: f64 },
}

impl SelectionPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            SelectionPolicy::Uniform => "uniform",
            SelectionPolicy::Feasibility { .. } => "feasibility",
        }
    }
}

/// The `[sim]` TOML section: time-domain scheduling knobs.
///
/// The default is fully inert — see the module docs' determinism contract.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimConfig {
    pub preset: ProfilePreset,
    /// Server-side round deadline in simulated seconds; uploads finishing
    /// later are dropped from aggregation. 0 disables.
    pub deadline_s: f64,
    /// Per-round per-client hard-dropout probability in [0, 1): the client
    /// trains but its upload never arrives. 0 disables.
    pub dropout: f64,
    /// Sampler over-provisioning factor (≥ 1): select
    /// `ceil(overselect · clients_per_round)` so stragglers can be dropped
    /// without starving the aggregate. 1 disables.
    pub overselect: f64,
    /// Base compute seconds per local step on a unit-speed device; a
    /// client's compute time is `compute_mult · compute_s · local_steps`.
    /// 0 disables the compute model (uplink-only finish times).
    pub compute_s: f64,
    /// Semi-synchronous aggregation: what the server does with uploads that
    /// miss the deadline. `Drop` (default) preserves the pre-carry
    /// behaviour bit-exactly.
    pub staleness: StalenessPolicy,
    /// How the sampler picks the cohort. `Uniform` (default) preserves the
    /// shuffle-and-truncate draw bit-exactly.
    pub selection: SelectionPolicy,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            preset: ProfilePreset::Uniform,
            deadline_s: 0.0,
            dropout: 0.0,
            overselect: 1.0,
            compute_s: 0.0,
            staleness: StalenessPolicy::Drop,
            selection: SelectionPolicy::Uniform,
        }
    }
}

impl SimConfig {
    /// Whether any scheduling *decision* is active. When false, participant
    /// selection and acceptance are exactly the PR 1 behaviour (profiles and
    /// `compute_s` only change reported seconds, never participation).
    pub fn scheduling_active(&self) -> bool {
        self.deadline_s > 0.0
            || self.dropout > 0.0
            || self.overselect > 1.0
            || self.staleness != StalenessPolicy::Drop
            || self.selection != SelectionPolicy::Uniform
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.deadline_s < 0.0 || !self.deadline_s.is_finite() {
            return Err(format!("sim.deadline_s must be finite and >= 0, got {}", self.deadline_s));
        }
        if !(0.0..1.0).contains(&self.dropout) {
            return Err(format!("sim.dropout must be in [0, 1), got {}", self.dropout));
        }
        if self.overselect < 1.0 || !self.overselect.is_finite() {
            return Err(format!("sim.overselect must be >= 1, got {}", self.overselect));
        }
        if self.compute_s < 0.0 || !self.compute_s.is_finite() {
            return Err(format!("sim.compute_s must be finite and >= 0, got {}", self.compute_s));
        }
        match self.preset {
            ProfilePreset::Heterogeneous { slow_every, slow_factor } => {
                if slow_every == 0 {
                    return Err("sim.slow_every must be >= 1".into());
                }
                if slow_factor < 1.0 || !slow_factor.is_finite() {
                    return Err(format!("sim.slow_factor must be >= 1, got {slow_factor}"));
                }
            }
            ProfilePreset::LongTail { sigma } => {
                if sigma < 0.0 || !sigma.is_finite() {
                    return Err(format!("sim.sigma must be finite and >= 0, got {sigma}"));
                }
            }
            ProfilePreset::Uniform => {}
        }
        if let StalenessPolicy::CarryDiscounted(a) = self.staleness {
            if !a.is_finite() || !(0.0..=1.0).contains(&a) {
                return Err(format!("sim.staleness_alpha must be in [0, 1], got {a}"));
            }
        }
        if let SelectionPolicy::Feasibility { beta } = self.selection {
            if !beta.is_finite() || !(0.0..=1.0).contains(&beta) {
                return Err(format!("sim.selection_beta must be in [0, 1], got {beta}"));
            }
        }
        Ok(())
    }
}

/// One client's simulated capability: its link plus how much slower than a
/// unit-speed device its local training runs.
#[derive(Clone, Copy, Debug)]
pub struct ClientProfile {
    pub link: LinkSpec,
    /// compute-time multiplier (1.0 = baseline device)
    pub compute_mult: f64,
}

/// Fate of one selected client in a scheduled round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClientFate {
    /// Upload arrived by the deadline and entered the aggregate.
    Accepted,
    /// Finished after the deadline: the bytes crossed the wire but the
    /// server discarded them (wasted traffic; residual restored).
    Straggler,
    /// Hard dropout: the upload never arrived (no traffic; residual
    /// restored).
    Offline,
}

/// The uplink-phase duration implied by a round's fates and finish times:
/// the slowest accepted upload — unless a deadline is set and anyone missed
/// it, in which case the server waits out the full deadline before closing
/// the round. Shared by [`Scheduler::plan_round`] and the service-mode
/// round loop, which recomputes fates from real arrivals but must close the
/// simulated clock identically.
/// Simulated tier-1 backhaul time: `bytes` of merged edge frames shipped
/// hub-ward over `edges` parallel links of `bps` bits/s each. The per-edge
/// byte split is approximated as even (mean spread) — edges serve
/// equal-sized cohort slices, so their merged frames are statistically
/// interchangeable. Diagnostic only: it never enters `sim_seconds`, which
/// is digested and must stay identical between flat and two-tier runs.
pub fn backhaul_time(bytes: usize, edges: usize, bps: f64) -> f64 {
    if edges == 0 || bytes == 0 {
        0.0
    } else if bps > 0.0 {
        (bytes as f64 * 8.0) / (bps * edges as f64)
    } else {
        // a zero/negative/NaN backhaul rate ships nothing, ever: surface
        // "never completes" instead of letting `0/0 → NaN` poison the
        // diagnostic column
        f64::INFINITY
    }
}

/// Smallest accepted link rate. Links configured at (or scaled down to)
/// zero, a negative value, or NaN are clamped here at profile construction
/// instead of poisoning every downstream `bytes / bps` with NaN or a
/// division by zero — a 10⁻³ B/s link is unambiguously "too slow for any
/// deadline" while keeping every finish time finite. Valid rates pass
/// through bit-identically (the digest contract).
pub const MIN_LINK_BPS: f64 = 1e-3;

/// Largest accepted compute slowdown: the long-tail draw `exp(σ·|N|)`
/// overflows to +∞ for large σ, and an infinite multiplier would drive
/// `compute_time` — and with it `sim_seconds` — non-finite.
pub const MAX_COMPUTE_MULT: f64 = 1e12;

/// Clamp a profile's arithmetic inputs into the range the time model is
/// total over. Finite positive rates, finite non-negative latencies and
/// finite positive multipliers are returned untouched (bit-identical).
fn sanitize_profile(mut p: ClientProfile) -> ClientProfile {
    // NaN and non-positive rates fail `> 0.0`; +∞ passes (an infinitely
    // fast link is a valid limit: every transfer takes 0 s)
    let fix_bps = |b: f64| if b > 0.0 { b } else { MIN_LINK_BPS };
    p.link.up_bps = fix_bps(p.link.up_bps);
    p.link.down_bps = fix_bps(p.link.down_bps);
    if !(p.link.latency_s.is_finite() && p.link.latency_s >= 0.0) {
        p.link.latency_s = 0.0;
    }
    if !p.compute_mult.is_finite() {
        p.compute_mult = MAX_COMPUTE_MULT;
    } else if p.compute_mult <= 0.0 {
        p.compute_mult = 1.0;
    }
    p
}

/// `bytes / bps`, total: a non-positive or NaN rate yields +∞ (the
/// transfer never completes) instead of `0/0 → NaN`. Post-sanitize
/// profiles never hit the guard; it protects directly-constructed ones.
fn transfer_s(bytes: usize, bps: f64) -> f64 {
    if bps > 0.0 {
        bytes as f64 / bps
    } else {
        f64::INFINITY
    }
}

pub fn uplink_close(cfg: &SimConfig, fates: &[ClientFate], finishes: &[f64]) -> f64 {
    debug_assert_eq!(fates.len(), finishes.len());
    let mut any_missed = false;
    let mut t_up: f64 = 0.0;
    for (&fate, &finish) in fates.iter().zip(finishes) {
        if fate == ClientFate::Accepted {
            t_up = f64::max(t_up, finish);
        } else {
            any_missed = true;
        }
    }
    if cfg.deadline_s > 0.0 && any_missed {
        t_up = cfg.deadline_s;
    }
    t_up
}

/// Per-client profiles + the run's simulated clock. Scheduling *policy*
/// (deadline, dropout, over-selection) stays in [`SimConfig`], which the
/// round loop passes per call — so a test (or a live reconfiguration) can
/// change the policy mid-run without rebuilding profiles.
#[derive(Clone, Debug)]
pub struct Scheduler {
    profiles: Vec<ClientProfile>,
    clock: f64,
}

impl Scheduler {
    /// Build per-client profiles by applying `preset` to the base network's
    /// links. `seed` only feeds the long-tail draw (deterministic per run).
    pub fn new(network: &Network, preset: ProfilePreset, seed: u64) -> Self {
        let scaled = |link: LinkSpec, f: f64| ClientProfile {
            link: LinkSpec {
                up_bps: link.up_bps / f,
                down_bps: link.down_bps / f,
                latency_s: link.latency_s,
            },
            compute_mult: f,
        };
        let profiles: Vec<ClientProfile> = match preset {
            ProfilePreset::Uniform => network
                .links
                .iter()
                .map(|&link| ClientProfile { link, compute_mult: 1.0 })
                .collect(),
            ProfilePreset::Heterogeneous { slow_every, slow_factor } => network
                .links
                .iter()
                .enumerate()
                .map(|(k, &link)| {
                    if slow_every > 0 && k % slow_every == slow_every - 1 {
                        scaled(link, slow_factor)
                    } else {
                        ClientProfile { link, compute_mult: 1.0 }
                    }
                })
                .collect(),
            ProfilePreset::LongTail { sigma } => {
                let mut rng = Rng::new(seed ^ 0x10_46_7A11);
                network
                    .links
                    .iter()
                    .map(|&link| {
                        let f = (sigma * (rng.normal() as f64).abs()).exp();
                        scaled(link, f)
                    })
                    .collect()
            }
        };
        // clamp degenerate arithmetic inputs (zero-rate links, NaN
        // latencies, overflowed long-tail multipliers) once, here, so every
        // downstream divide stays finite; valid profiles are untouched
        let profiles = profiles.into_iter().map(sanitize_profile).collect();
        Scheduler { profiles, clock: 0.0 }
    }

    pub fn clients(&self) -> usize {
        self.profiles.len()
    }

    pub fn profile(&self, client: usize) -> &ClientProfile {
        &self.profiles[client % self.profiles.len()]
    }

    /// Cumulative simulated seconds since the start of the run.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Advance the round clock by `dt` seconds; returns the new clock.
    pub fn advance(&mut self, dt: f64) -> f64 {
        self.clock += dt;
        self.clock
    }

    /// Simulated local-training time for `client`.
    pub fn compute_time(&self, cfg: &SimConfig, client: usize, local_steps: usize) -> f64 {
        self.profile(client).compute_mult * cfg.compute_s * local_steps.max(1) as f64
    }

    /// Simulated upload time for `bytes` on `client`'s link.
    pub fn uplink_time(&self, client: usize, bytes: usize) -> f64 {
        let l = &self.profile(client).link;
        l.latency_s + transfer_s(bytes, l.up_bps)
    }

    /// Multicast completion time: the slowest participant's downlink.
    pub fn broadcast_time(&self, bytes: usize, participants: &[usize]) -> f64 {
        participants
            .iter()
            .map(|&k| {
                let l = &self.profile(k).link;
                l.latency_s + transfer_s(bytes, l.down_bps)
            })
            .fold(0.0, f64::max)
    }

    /// Decide every selected client's fate for one round and return the
    /// uplink-phase duration.
    ///
    /// `bytes[i]` is participant `participants[i]`'s wire payload size.
    /// Dropout draws are taken from `rng` in participant order (one draw per
    /// participant when `cfg.dropout > 0`), so the plan is independent of
    /// worker count. `fates`/`finishes` are reusable output buffers.
    ///
    /// The uplink phase lasts until the slowest accepted upload — unless a
    /// deadline is set and anyone missed it, in which case the server waits
    /// out the full deadline before closing the round.
    #[allow(clippy::too_many_arguments)]
    pub fn plan_round(
        &self,
        cfg: &SimConfig,
        participants: &[usize],
        bytes: &[usize],
        local_steps: usize,
        rng: &mut Rng,
        fates: &mut Vec<ClientFate>,
        finishes: &mut Vec<f64>,
    ) -> f64 {
        debug_assert_eq!(participants.len(), bytes.len());
        fates.clear();
        finishes.clear();
        let deadline = cfg.deadline_s;
        for (&cid, &b) in participants.iter().zip(bytes) {
            let offline = cfg.dropout > 0.0 && rng.f64() < cfg.dropout;
            let finish = self.compute_time(cfg, cid, local_steps) + self.uplink_time(cid, b);
            let fate = if offline {
                ClientFate::Offline
            } else if deadline > 0.0 && finish > deadline {
                ClientFate::Straggler
            } else {
                ClientFate::Accepted
            };
            fates.push(fate);
            finishes.push(finish);
        }
        uplink_close(cfg, fates, finishes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backhaul_time_spreads_over_parallel_edges() {
        assert_eq!(backhaul_time(0, 0, 1e8), 0.0, "no edges, no backhaul");
        assert_eq!(backhaul_time(1000, 0, 1e8), 0.0);
        // 1000 bytes over one 8 kbit/s link = 1 s; two parallel links halve it
        assert!((backhaul_time(1000, 1, 8000.0) - 1.0).abs() < 1e-12);
        assert!((backhaul_time(1000, 2, 8000.0) - 0.5).abs() < 1e-12);
    }

    fn net(n: usize) -> Network {
        Network::uniform(n, LinkSpec { up_bps: 1000.0, down_bps: 2000.0, latency_s: 0.0 })
    }

    fn plan(
        sched: &Scheduler,
        cfg: &SimConfig,
        parts: &[usize],
        bytes: &[usize],
        seed: u64,
    ) -> (Vec<ClientFate>, Vec<f64>, f64) {
        let mut rng = Rng::new(seed);
        let mut fates = Vec::new();
        let mut finishes = Vec::new();
        let t = sched.plan_round(cfg, parts, bytes, 1, &mut rng, &mut fates, &mut finishes);
        (fates, finishes, t)
    }

    #[test]
    fn inert_config_reproduces_passive_estimator() {
        let network = net(3);
        let sched = Scheduler::new(&network, ProfilePreset::Uniform, 1);
        let cfg = SimConfig::default();
        assert!(!cfg.scheduling_active());
        let (fates, finishes, t) = plan(&sched, &cfg, &[0, 1, 2], &[1000, 3000, 500], 7);
        assert!(fates.iter().all(|&f| f == ClientFate::Accepted));
        let legacy = network.uplink_time(&[(0, 1000), (1, 3000), (2, 500)]);
        assert_eq!(t.to_bits(), legacy.to_bits(), "must be bit-identical to Network::uplink_time");
        assert_eq!(finishes[1].to_bits(), 3.0f64.to_bits());
    }

    #[test]
    fn deadline_drops_stragglers_and_waits_out_the_deadline() {
        let sched = Scheduler::new(&net(3), ProfilePreset::Uniform, 1);
        let cfg = SimConfig { deadline_s: 1.0, ..Default::default() };
        // finishes: 1000/1000 = 1.0 (makes it), 3000/1000 = 3.0 (late)
        let (fates, _, t) = plan(&sched, &cfg, &[0, 1], &[1000, 3000], 7);
        assert_eq!(fates, vec![ClientFate::Accepted, ClientFate::Straggler]);
        assert_eq!(t, 1.0, "server waits until the deadline when anyone misses");
    }

    #[test]
    fn deadline_closes_early_when_everyone_arrives() {
        let sched = Scheduler::new(&net(2), ProfilePreset::Uniform, 1);
        let cfg = SimConfig { deadline_s: 10.0, ..Default::default() };
        let (fates, _, t) = plan(&sched, &cfg, &[0, 1], &[1000, 2000], 7);
        assert!(fates.iter().all(|&f| f == ClientFate::Accepted));
        assert_eq!(t, 2.0);
    }

    #[test]
    fn compute_model_shifts_finish_times() {
        let network = net(4);
        let sched = Scheduler::new(
            &network,
            ProfilePreset::Heterogeneous { slow_every: 2, slow_factor: 10.0 },
            1,
        );
        let cfg = SimConfig { compute_s: 0.5, ..Default::default() };
        // client 0: fast (1× compute, full link); client 1: slow (10×, link/10)
        assert_eq!(sched.compute_time(&cfg, 0, 2), 1.0);
        assert_eq!(sched.compute_time(&cfg, 1, 2), 10.0);
        assert_eq!(sched.uplink_time(0, 1000), 1.0);
        assert_eq!(sched.uplink_time(1, 1000), 10.0);
    }

    #[test]
    fn dropout_draws_follow_rng_and_spare_traffic() {
        let sched = Scheduler::new(&net(4), ProfilePreset::Uniform, 1);
        let cfg = SimConfig { dropout: 0.5, ..Default::default() };
        // deterministic per seed; over many seeds roughly half drop
        let mut offline = 0usize;
        let mut total = 0usize;
        for seed in 0..200u64 {
            let (fates, _, _) = plan(&sched, &cfg, &[0, 1, 2, 3], &[100; 4], seed);
            offline += fates.iter().filter(|&&f| f == ClientFate::Offline).count();
            total += fates.len();
        }
        let rate = offline as f64 / total as f64;
        assert!((rate - 0.5).abs() < 0.1, "offline rate {rate}");
        // same seed → same plan
        let a = plan(&sched, &cfg, &[0, 1, 2, 3], &[100; 4], 3);
        let b = plan(&sched, &cfg, &[0, 1, 2, 3], &[100; 4], 3);
        assert_eq!(a.0, b.0);
    }

    #[test]
    fn longtail_profiles_are_deterministic_and_bounded_below() {
        let network = net(32);
        let a = Scheduler::new(&network, ProfilePreset::LongTail { sigma: 0.8 }, 42);
        let b = Scheduler::new(&network, ProfilePreset::LongTail { sigma: 0.8 }, 42);
        for k in 0..32 {
            assert_eq!(a.profile(k).compute_mult.to_bits(), b.profile(k).compute_mult.to_bits());
            assert!(a.profile(k).compute_mult >= 1.0);
            assert!(a.profile(k).link.up_bps <= network.links[k].up_bps);
        }
        let c = Scheduler::new(&network, ProfilePreset::LongTail { sigma: 0.8 }, 43);
        let differs = (0..32).any(|k| a.profile(k).compute_mult != c.profile(k).compute_mult);
        assert!(differs, "different seeds must draw different tails");
    }

    #[test]
    fn degenerate_links_are_sanitized_at_construction() {
        let links = vec![
            LinkSpec { up_bps: 0.0, down_bps: -5.0, latency_s: f64::NAN },
            LinkSpec { up_bps: f64::NAN, down_bps: 0.0, latency_s: -1.0 },
            LinkSpec { up_bps: -3.0, down_bps: f64::NAN, latency_s: f64::INFINITY },
        ];
        let sched = Scheduler::new(&Network { links }, ProfilePreset::Uniform, 1);
        for c in 0..3 {
            let p = sched.profile(c);
            assert!(p.link.up_bps > 0.0 && p.link.up_bps.is_finite(), "client {c} up_bps");
            assert!(p.link.down_bps > 0.0 && p.link.down_bps.is_finite(), "client {c} down_bps");
            assert!(p.link.latency_s == 0.0, "client {c} latency");
            // the pre-guard failure mode: `0 bytes / 0 bps` was NaN, which
            // `finish > deadline` silently classified as Accepted
            assert!(sched.uplink_time(c, 0).is_finite(), "client {c} zero-byte uplink");
            assert!(sched.uplink_time(c, 1000).is_finite(), "client {c} uplink");
        }
        assert!(sched.broadcast_time(512, &[0, 1, 2]).is_finite());
        // a sanitized dead link is catastrophically slow, not fast: it must
        // straggle under any realistic deadline rather than sneak in as a
        // zero-cost accept
        let cfg = SimConfig { deadline_s: 60.0, ..Default::default() };
        let (fates, finishes, t) = plan(&sched, &cfg, &[0, 1, 2], &[100; 3], 7);
        assert!(fates.iter().all(|&f| f == ClientFate::Straggler));
        assert!(finishes.iter().all(|f| f.is_finite()));
        assert!(t.is_finite());
    }

    #[test]
    fn valid_profiles_pass_through_sanitizing_bit_identically() {
        // the digest contract: the guard must be invisible on healthy input
        let links = vec![
            LinkSpec { up_bps: 24_000.0, down_bps: 96_000.0, latency_s: 0.004 },
            LinkSpec { up_bps: 1_200.0, down_bps: 96_000.0, latency_s: 0.008 },
        ];
        let network = Network { links: links.clone() };
        let sched = Scheduler::new(&network, ProfilePreset::Uniform, 1);
        for (c, l) in links.iter().enumerate() {
            assert_eq!(sched.profile(c).link.up_bps.to_bits(), l.up_bps.to_bits());
            assert_eq!(sched.profile(c).link.down_bps.to_bits(), l.down_bps.to_bits());
            assert_eq!(sched.profile(c).link.latency_s.to_bits(), l.latency_s.to_bits());
            assert_eq!(sched.profile(c).compute_mult.to_bits(), 1.0f64.to_bits());
        }
        // infinitely fast is a valid limit, not a defect: transfers take 0 s
        let inf = Network {
            links: vec![LinkSpec { up_bps: f64::INFINITY, down_bps: f64::INFINITY, latency_s: 0.0 }],
        };
        let fast = Scheduler::new(&inf, ProfilePreset::Uniform, 1);
        assert_eq!(fast.uplink_time(0, 4096), 0.0);
        assert_eq!(fast.broadcast_time(4096, &[0]), 0.0);
    }

    #[test]
    fn extreme_longtail_sigma_keeps_every_time_finite() {
        // exp(σ·|N|) overflows to +∞ at large σ; before the clamp that made
        // compute_time = ∞ and up_bps = base/∞ = 0 → uplink_time = ∞ or NaN
        let network = net(16);
        let sched = Scheduler::new(&network, ProfilePreset::LongTail { sigma: 400.0 }, 7);
        let cfg = SimConfig { deadline_s: 1.0, compute_s: 0.01, ..Default::default() };
        for c in 0..16 {
            let p = sched.profile(c);
            assert!(p.compute_mult.is_finite() && p.compute_mult >= 1.0, "client {c} mult");
            assert!(p.link.up_bps > 0.0, "client {c} up_bps");
            assert!(sched.compute_time(&cfg, c, 1).is_finite(), "client {c} compute");
            assert!(sched.uplink_time(c, 500).is_finite(), "client {c} uplink");
        }
        let parts: Vec<usize> = (0..16).collect();
        let (_, finishes, t) = plan(&sched, &cfg, &parts, &[500; 16], 3);
        assert!(finishes.iter().all(|f| f.is_finite()), "finish times must stay finite");
        assert!(t.is_finite(), "uplink-phase close must stay finite");
    }

    #[test]
    fn backhaul_time_guards_degenerate_rates() {
        assert_eq!(backhaul_time(1000, 2, 0.0), f64::INFINITY);
        assert_eq!(backhaul_time(1000, 2, -8.0), f64::INFINITY);
        assert_eq!(backhaul_time(1000, 2, f64::NAN), f64::INFINITY);
        // nothing to ship is 0 s regardless of the rate's health
        assert_eq!(backhaul_time(0, 2, 0.0), 0.0);
        assert_eq!(backhaul_time(1000, 0, 0.0), 0.0);
        assert_eq!(backhaul_time(1000, 2, f64::INFINITY), 0.0);
    }

    #[test]
    fn clock_accumulates() {
        let mut sched = Scheduler::new(&net(1), ProfilePreset::Uniform, 1);
        assert_eq!(sched.clock(), 0.0);
        assert_eq!(sched.advance(1.5), 1.5);
        assert_eq!(sched.advance(0.5), 2.0);
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        let ok = SimConfig::default();
        assert!(ok.validate().is_ok());
        assert!(SimConfig { dropout: 1.0, ..ok }.validate().is_err());
        assert!(SimConfig { dropout: -0.1, ..ok }.validate().is_err());
        assert!(SimConfig { overselect: 0.5, ..ok }.validate().is_err());
        assert!(SimConfig { deadline_s: -1.0, ..ok }.validate().is_err());
        assert!(SimConfig { compute_s: f64::NAN, ..ok }.validate().is_err());
        let bad_het = SimConfig {
            preset: ProfilePreset::Heterogeneous { slow_every: 0, slow_factor: 2.0 },
            ..ok
        };
        assert!(bad_het.validate().is_err());
        let bad_tail =
            SimConfig { preset: ProfilePreset::LongTail { sigma: -1.0 }, ..ok };
        assert!(bad_tail.validate().is_err());
        let bad_alpha =
            SimConfig { staleness: StalenessPolicy::CarryDiscounted(1.5), ..ok };
        assert!(bad_alpha.validate().is_err());
        let nan_alpha =
            SimConfig { staleness: StalenessPolicy::CarryDiscounted(f64::NAN), ..ok };
        assert!(nan_alpha.validate().is_err());
        let bad_beta =
            SimConfig { selection: SelectionPolicy::Feasibility { beta: -0.2 }, ..ok };
        assert!(bad_beta.validate().is_err());
        let ok_carry = SimConfig { staleness: StalenessPolicy::Carry, ..ok };
        assert!(ok_carry.validate().is_ok());
    }

    #[test]
    fn staleness_policy_alpha_and_carry_flags() {
        assert_eq!(StalenessPolicy::Drop.alpha(), 0.0);
        assert!(!StalenessPolicy::Drop.carries());
        assert_eq!(StalenessPolicy::Carry.alpha(), 1.0);
        assert!(StalenessPolicy::Carry.carries());
        assert_eq!(StalenessPolicy::CarryDiscounted(0.25).alpha(), 0.25);
        assert!(StalenessPolicy::CarryDiscounted(0.25).carries());
        // a zero discount carries nothing — the Drop-equivalence guarantee
        assert!(!StalenessPolicy::CarryDiscounted(0.0).carries());
        assert_eq!(StalenessPolicy::Carry.name(), "carry");
        assert_eq!(SelectionPolicy::Feasibility { beta: 0.5 }.name(), "feasibility");
    }

    #[test]
    fn semi_sync_knobs_activate_scheduling() {
        let base = SimConfig::default();
        assert!(!base.scheduling_active());
        let carry = SimConfig { staleness: StalenessPolicy::Carry, ..base };
        assert!(carry.scheduling_active());
        let feas =
            SimConfig { selection: SelectionPolicy::Feasibility { beta: 0.0 }, ..base };
        assert!(feas.scheduling_active());
    }
}
