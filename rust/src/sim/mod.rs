//! Network simulation: hub-and-spoke topology, bytes → seconds, and the
//! time-domain round scheduler (deadlines, stragglers, dropouts).
pub mod network;
pub mod scheduler;

pub use network::{LinkSpec, Network};
pub use scheduler::{ClientFate, ClientProfile, ProfilePreset, Scheduler, SimConfig};
