//! Network simulation: hub-and-spoke topology, bytes → seconds.
pub mod network;

pub use network::{LinkSpec, Network};
