//! Network simulation: hub-and-spoke topology, bytes → seconds, the
//! time-domain round scheduler (deadlines, stragglers, dropouts) and the
//! semi-synchronous staleness queue (late-upload carry-over).
pub mod network;
pub mod scheduler;
pub mod staleness;

pub use network::{LinkSpec, Network};
pub use scheduler::{
    ClientFate, ClientProfile, ProfilePreset, Scheduler, SelectionPolicy, SimConfig,
    StalenessPolicy,
};
pub use staleness::{StaleEntry, StaleQueue};
