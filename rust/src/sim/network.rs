//! Hub-and-spoke network simulator.
//!
//! The paper's FL topology: every client talks only to the central server.
//! Given byte counts from the wire layer, the simulator converts traffic
//! into time under per-link bandwidth/latency, modelling the round as
//!
//!   round_time = max_k (uplink_k) + aggregate_compute + broadcast
//!
//! (clients upload in parallel on their own links; the hub's downlink is a
//! multicast costed once at the slowest client's bandwidth). This gives the
//! wall-clock view of the paper's communication-overhead tables: bytes are
//! the primary metric, simulated seconds are reported alongside.

/// Per-link characteristics (asymmetric, like consumer connections).
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// client → server bytes/second
    pub up_bps: f64,
    /// server → client bytes/second
    pub down_bps: f64,
    /// one-way latency, seconds
    pub latency_s: f64,
}

impl Default for LinkSpec {
    fn default() -> Self {
        // 20 Mbit/s up, 100 Mbit/s down, 25 ms — a typical consumer link
        LinkSpec { up_bps: 2.5e6, down_bps: 12.5e6, latency_s: 0.025 }
    }
}

/// Hub-and-spoke network over `clients` links.
#[derive(Clone, Debug)]
pub struct Network {
    pub links: Vec<LinkSpec>,
}

impl Network {
    pub fn uniform(clients: usize, spec: LinkSpec) -> Self {
        Network { links: vec![spec; clients] }
    }

    /// Heterogeneous helper: every `slow_every`-th client gets `slow` links.
    pub fn heterogeneous(
        clients: usize,
        fast: LinkSpec,
        slow: LinkSpec,
        slow_every: usize,
    ) -> Self {
        let links = (0..clients)
            .map(|k| if slow_every > 0 && k % slow_every == slow_every - 1 { slow } else { fast })
            .collect();
        Network { links }
    }

    pub fn clients(&self) -> usize {
        self.links.len()
    }

    /// Time for the parallel uplink phase: slowest participating client.
    pub fn uplink_time(&self, uplink_bytes: &[(usize, usize)]) -> f64 {
        uplink_bytes
            .iter()
            .map(|&(k, bytes)| {
                let l = &self.links[k % self.links.len()];
                l.latency_s + bytes as f64 / l.up_bps
            })
            .fold(0.0, f64::max)
    }

    /// Time for the broadcast phase to a set of participants: the multicast
    /// completes when the slowest participant has the payload.
    pub fn broadcast_time(&self, bytes: usize, participants: &[usize]) -> f64 {
        participants
            .iter()
            .map(|&k| {
                let l = &self.links[k % self.links.len()];
                l.latency_s + bytes as f64 / l.down_bps
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uplink_is_slowest_client() {
        let spec = LinkSpec { up_bps: 1000.0, down_bps: 1000.0, latency_s: 0.0 };
        let net = Network::uniform(3, spec);
        let t = net.uplink_time(&[(0, 1000), (1, 3000), (2, 500)]);
        assert!((t - 3.0).abs() < 1e-9);
    }

    #[test]
    fn broadcast_counts_once_at_slowest() {
        let fast = LinkSpec { up_bps: 1e6, down_bps: 1e6, latency_s: 0.0 };
        let slow = LinkSpec { up_bps: 1e6, down_bps: 1e3, latency_s: 0.0 };
        let net = Network::heterogeneous(4, fast, slow, 4);
        let t = net.broadcast_time(1000, &[0, 1, 2, 3]);
        assert!((t - 1.0).abs() < 1e-9, "t={t}"); // limited by the one slow link
    }

    #[test]
    fn latency_floors_small_messages() {
        let net = Network::uniform(2, LinkSpec { up_bps: 1e9, down_bps: 1e9, latency_s: 0.05 });
        let t = net.uplink_time(&[(0, 1), (1, 1)]);
        assert!(t >= 0.05);
    }

    #[test]
    fn empty_participation_is_free() {
        let net = Network::uniform(2, LinkSpec::default());
        assert_eq!(net.uplink_time(&[]), 0.0);
        assert_eq!(net.broadcast_time(100, &[]), 0.0);
    }
}
