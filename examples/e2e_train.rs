//! End-to-end driver (DESIGN.md validation requirement): exercises the full
//! three-layer stack on a real small workload and logs the loss curve.
//!
//! Layers exercised:
//!   L1  Pallas-specified compression math (equivalence-tested primitives)
//!   L2  JAX resnet8 fwd/bwd via AOT HLO artifacts on PJRT (build once)
//!   L3  Rust coordinator: non-IID partition, four-scheme compression,
//!       sparse wire transport, byte accounting, network simulation
//!
//! Trains federated DGCwGMF on synthetic Mod-CIFAR10 (EMD 0.99) and prints
//! train loss / test accuracy every round; writes results/e2e/curve.csv.
//! Recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_train [-- <rounds>]
//! ```

use fedgmf::config::RunConfig;
use fedgmf::coordinator::round::FlRun;
use fedgmf::experiments::workload::{build_engine, build_workload};
use fedgmf::sim::network::Network;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(60);

    let mut cfg = RunConfig::default();
    cfg.technique = fedgmf::compress::CompressorKind::DgcWgmf;
    cfg.emd = 0.99;
    cfg.rounds = rounds;
    cfg.clients = 10;
    cfg.samples_per_client = 120;
    cfg.eval_every = 5;
    println!("end-to-end run: {}", cfg.describe());

    let workload = build_workload(&cfg)?;
    println!(
        "partitioned: {} clients, achieved EMD {:.3}",
        workload.shards.len(),
        workload.achieved_emd
    );

    let mut ctx = None;
    let mut engine = build_engine(&cfg, Path::new("artifacts"), &mut ctx)?;
    println!(
        "engine ready: P = {} parameters (resnet8 via PJRT artifacts)",
        engine.param_count()
    );

    let network = Network::uniform(cfg.clients, Default::default());
    let mut run =
        FlRun::new(engine.as_ref(), workload.shards, workload.test, network, cfg.fl_config());

    println!(
        "\n{:>5} {:>12} {:>10} {:>10} {:>12} {:>10}",
        "round", "train_loss", "test_acc", "agg_nnz", "uplink(KB)", "sim(s)"
    );
    for round in 0..rounds {
        let rec = run.step_round(engine.as_mut(), round)?;
        println!(
            "{:>5} {:>12.4} {:>10} {:>10} {:>12.1} {:>10.2}",
            rec.round,
            rec.train_loss,
            if rec.test_accuracy > 0.0 { format!("{:.4}", rec.test_accuracy) } else { "-".into() },
            rec.aggregate_nnz,
            rec.uplink_bytes as f64 / 1e3,
            rec.sim_seconds,
        );
    }

    let summary = run.summary();
    std::fs::create_dir_all("results/e2e")?;
    summary.recorder.write_csv(Path::new("results/e2e/curve.csv"))?;
    std::fs::write("results/e2e/summary.json", summary.recorder.summary_json().to_pretty())?;
    println!(
        "\nfinal: acc {:.4} | traffic {:.4} GB | mask overlap {:.3}\ncurve: results/e2e/curve.csv",
        summary.final_accuracy, summary.total_traffic_gb, summary.mean_mask_overlap
    );
    Ok(())
}
