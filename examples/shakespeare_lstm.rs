//! Shakespeare next-char prediction over 100 naturally non-IID speakers
//! (paper §4.3): a charlstm trained federated with DGCwGMF vs DGC.
//!
//! ```sh
//! cargo run --release --example shakespeare_lstm [-- <rounds>]
//! ```

use fedgmf::compress::CompressorKind;
use fedgmf::config::RunConfig;
use fedgmf::experiments::runner::{comparison_rows, execute};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);

    let mut ctx = None;
    let mut rows = Vec::new();
    for kind in [CompressorKind::Dgc, CompressorKind::DgcWgmf] {
        let mut cfg = RunConfig::shakespeare();
        cfg.technique = kind;
        cfg.rounds = rounds;
        cfg.eval_every = (rounds / 4).max(1);
        println!("running {} ({} speakers, {} rounds)...", kind.name(), cfg.clients, rounds);
        let (summary, emd) = execute(&cfg, Path::new("artifacts"), &mut ctx)?;
        println!(
            "  {:<8} acc {:.4} | traffic {:.4} GB | char-EMD {:.4}",
            kind.name(),
            summary.final_accuracy,
            summary.total_traffic_gb,
            emd
        );
        rows.push((kind.name().to_string(), summary));
    }
    println!("\n{}", comparison_rows(&rows));
    Ok(())
}
