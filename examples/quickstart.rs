//! Quickstart: federated training with Global Momentum Fusion in ~40 lines.
//!
//! Runs DGCwGMF on a small non-IID synthetic CIFAR workload and prints the
//! headline numbers: accuracy + byte-exact communication traffic. Uses the
//! AOT artifacts when present (run `make artifacts` once), otherwise falls
//! back to the self-contained native engine so the example always runs —
//! CI smoke-runs it that way.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use fedgmf::config::{EngineKind, RunConfig, Scale};
use fedgmf::experiments::runner::execute;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // 1. describe the run: non-IID (EMD 0.99), keep top 10% of coordinates
    let mut cfg = RunConfig::default().with_scale(Scale::Quick);
    cfg.technique = fedgmf::compress::CompressorKind::DgcWgmf;
    cfg.emd = 0.99;
    cfg.rate = 0.1;
    cfg.rounds = 10;
    let artifacts = Path::new("artifacts");
    if fedgmf::runtime::manifest::Manifest::load(artifacts).is_err() || cfg!(not(feature = "pjrt"))
    {
        cfg.engine = EngineKind::Native; // no artifacts (or no pjrt build)
    }
    println!("config: {}", cfg.describe());

    // 2. run it (workload generation, partitioning, FL rounds, accounting)
    let mut ctx = None;
    let (summary, emd) = execute(&cfg, artifacts, &mut ctx)?;

    // 3. the paper's two metrics
    println!("achieved EMD:        {emd:.3}");
    println!("final top-1 acc:     {:.4}", summary.final_accuracy);
    println!("total traffic:       {:.4} GB", summary.total_traffic_gb);
    println!("  uplink:            {:.4} GB", summary.uplink_gb);
    println!("  downlink:          {:.4} GB", summary.downlink_gb);
    println!(
        "mean mask overlap:   {:.3}  (GMF raises this → smaller downlink)",
        summary.mean_mask_overlap
    );
    println!(
        "simulated wall time: {:.1} s over {} rounds",
        summary.sim_seconds,
        summary.recorder.rounds.len()
    );
    Ok(())
}
