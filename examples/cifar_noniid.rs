//! Non-IID CIFAR comparison: the paper's core claim in one runnable scene.
//!
//! Trains the same highly-skewed workload (Cifar10-6, EMD 1.35 — the
//! hardest row of Table 3) under all four techniques and prints the
//! accuracy/traffic comparison, demonstrating:
//!   * DGCwGM's growing downlink (server momentum, §2.1),
//!   * GMC's accuracy fragility under high EMD (§2.2),
//!   * DGCwGMF matching DGC's accuracy with less traffic.
//!
//! ```sh
//! cargo run --release --example cifar_noniid [-- <emd> <rounds>]
//! ```

use fedgmf::compress::CompressorKind;
use fedgmf::config::{RunConfig, Scale};
use fedgmf::experiments::runner::{comparison_rows, execute};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let emd: f64 = argv.first().and_then(|s| s.parse().ok()).unwrap_or(1.35);
    let rounds: usize = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(20);

    println!("workload: synthetic Mod-Cifar10, EMD target {emd}, {rounds} rounds, rate 0.1\n");
    let mut ctx = None;
    let mut rows = Vec::new();
    for kind in CompressorKind::ALL {
        let mut cfg = RunConfig::default().with_scale(Scale::Default);
        cfg.technique = kind;
        cfg.emd = emd;
        cfg.rounds = rounds;
        cfg.eval_every = (rounds / 4).max(1);
        let (summary, achieved) = execute(&cfg, Path::new("artifacts"), &mut ctx)?;
        println!(
            "  {:<8} done: acc {:.4}, traffic {:.4} GB (down {:.4}), achieved EMD {:.3}",
            kind.name(),
            summary.final_accuracy,
            summary.total_traffic_gb,
            summary.downlink_gb,
            achieved
        );
        rows.push((kind.name().to_string(), summary));
    }
    println!("\n{}", comparison_rows(&rows));
    println!(
        "expected shape (paper Table 3, Cifar10-6): DGCwGM has the largest traffic;\n\
         DGCwGMF the smallest, at accuracy >= DGC; GMC degrades at high EMD."
    );
    Ok(())
}
