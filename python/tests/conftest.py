import os
import sys

# make `compile.*` importable regardless of pytest invocation directory
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
