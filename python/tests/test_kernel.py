"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps vector lengths (block-aligned and ragged), value scales
(including denormal-adjacent and large magnitudes) and hyper-parameters.
Tolerances allow FMA/reassociation differences between the Pallas interpret
path and the jnp oracle: rtol=1e-4, atol=1e-5 relative to unit-normalised
vectors.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gmf, ref

TOL = dict(rtol=1e-4, atol=1e-5)


def vecs(n, seed, scale=1.0, count=1):
    rng = np.random.default_rng(seed)
    out = [jnp.asarray(rng.normal(size=n) * scale, jnp.float32) for _ in range(count)]
    return out[0] if count == 1 else out


# ----------------------------------------------------------------- sumsq ---


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_sumsq_matches_ref(n, seed, scale):
    x = vecs(n, seed, scale)
    got = gmf.sumsq(x)
    want = ref.sumsq(x)
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_sumsq_zero_vector():
    assert float(gmf.sumsq(jnp.zeros(2048))) == 0.0


def test_sumsq_exact_block_multiple():
    x = jnp.ones(gmf.BLOCK * 3)
    np.testing.assert_allclose(gmf.sumsq(x), gmf.BLOCK * 3, rtol=1e-6)


# ------------------------------------------------------------- gmf_score ---


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=4000),
    seed=st.integers(0, 2**31 - 1),
    tau=st.sampled_from([0.0, 0.1, 0.3, 0.6, 1.0]),
)
def test_gmf_score_matches_ref(n, seed, tau):
    v, m = vecs(n, seed, count=2)
    np.testing.assert_allclose(gmf.gmf_score(v, m, tau), ref.gmf_score(v, m, tau), **TOL)


def test_gmf_score_tau_zero_is_normalized_abs_v():
    """tau=0 degenerates to DGC's |V| selection score (up to normalisation)."""
    v, m = vecs(1500, 7, count=2)
    z = gmf.gmf_score(v, m, 0.0)
    np.testing.assert_allclose(z, jnp.abs(v) / jnp.linalg.norm(v), **TOL)
    # ordering identical to |V|'s ordering
    assert list(np.argsort(np.asarray(z))) == list(np.argsort(np.abs(np.asarray(v))))


def test_gmf_score_tau_one_ignores_v_magnitudes():
    v, m = vecs(1200, 9, count=2)
    z1 = gmf.gmf_score(v, m, 1.0)
    z2 = gmf.gmf_score(v * 123.0, m, 1.0)
    np.testing.assert_allclose(z1, z2, **TOL)


def test_gmf_score_zero_momentum_safe():
    """M=0 (first round) must not produce NaN -- eps guards the norm."""
    v = vecs(999, 3)
    z = gmf.gmf_score(v, jnp.zeros_like(v), 0.5)
    assert np.isfinite(np.asarray(z)).all()


def test_gmf_score_scale_invariance():
    """N() makes the score invariant to the scale of each input."""
    v, m = vecs(2000, 11, count=2)
    z1 = gmf.gmf_score(v, m, 0.4)
    z2 = gmf.gmf_score(v * 0.01, m * 100.0, 0.4)
    np.testing.assert_allclose(z1, z2, **TOL)


# ------------------------------------------------------------ dgc_update ---


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=4000),
    seed=st.integers(0, 2**31 - 1),
    alpha=st.sampled_from([0.0, 0.5, 0.9, 0.99]),
)
def test_dgc_update_matches_ref(n, seed, alpha):
    u, v, g = vecs(n, seed, count=3)
    got = gmf.dgc_update(u, v, g, alpha)
    want = ref.dgc_update(u, v, g, alpha)
    for x, y in zip(got, want):
        np.testing.assert_allclose(x, y, **TOL)


def test_dgc_update_alpha_zero_is_plain_accumulate():
    u, v, g = vecs(1025, 5, count=3)
    u2, v2 = gmf.dgc_update(u, v, g, 0.0)
    np.testing.assert_allclose(u2, g, **TOL)
    np.testing.assert_allclose(v2, v + g, **TOL)


# ------------------------------------------------------------ mask_apply ---


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=4000),
    seed=st.integers(0, 2**31 - 1),
    frac=st.floats(min_value=0.01, max_value=0.99),
)
def test_mask_apply_matches_ref(n, seed, frac):
    u, v, z = vecs(n, seed, count=3)
    k = max(1, int(frac * n))
    mask = ref.topk_mask(jnp.abs(z), k)
    got = gmf.mask_apply(u, v, mask)
    want = ref.mask_apply(u, v, mask)
    for x, y in zip(got, want):
        np.testing.assert_allclose(x, y, **TOL)


def test_mask_apply_partition_invariant():
    """G + V' == V exactly: transmitted and accumulated parts partition V."""
    u, v, z = vecs(3100, 13, count=3)
    mask = ref.topk_mask(jnp.abs(z), 310)
    g_out, _u2, v2 = gmf.mask_apply(u, v, mask)
    np.testing.assert_allclose(np.asarray(g_out) + np.asarray(v2), np.asarray(v), rtol=1e-6)


def test_mask_apply_orthogonality():
    """<G, V'> == 0: the paper's orthogonality property (Fig. 2)."""
    u, v, z = vecs(2048, 17, count=3)
    mask = ref.topk_mask(jnp.abs(z), 204)
    g_out, _u2, v2 = gmf.mask_apply(u, v, mask)
    assert float(jnp.dot(g_out, v2)) == 0.0


# -------------------------------------------------------- composite step ---


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tau=st.sampled_from([0.0, 0.3, 0.6]),
    rate=st.sampled_from([0.1, 0.5]),
)
def test_dgc_gmf_step_matches_ref(seed, tau, rate):
    n = 2500
    u, v, m, g, gh = vecs(n, seed, count=5)
    k = int(rate * n)
    got = gmf.dgc_gmf_step(u, v, m, g, gh, 0.9, 0.8, tau, k)
    want = ref.dgc_gmf_step(u, v, m, g, gh, 0.9, 0.8, tau, k)
    for x, y in zip(got, want):
        np.testing.assert_allclose(x, y, **TOL)


def test_dgc_gmf_step_sparsity():
    """The transmitted gradient has at most k nonzeros (ties can reduce)."""
    n, k = 4000, 400
    u, v, m, g = vecs(n, 23, count=4)
    g_out, u2, v2, m2, thr = gmf.dgc_gmf_step(u, v, m, g, jnp.zeros(n), 0.9, 0.8, 0.3, k)
    nnz = int(jnp.sum(g_out != 0.0))
    assert nnz <= k + 5  # + tolerance for exact-tie threshold hits
    assert nnz >= int(0.9 * k)


def test_dgc_gmf_step_tau_zero_equals_dgc_selection():
    """tau=0: the mask equals DGC's top-k |V| mask."""
    n, k = 3000, 300
    u, v, m, g = vecs(n, 29, count=4)
    g_out, *_ = gmf.dgc_gmf_step(u, v, m, g, jnp.zeros(n), 0.9, 0.0, 0.0, k)
    u1, v1 = ref.dgc_update(u, v, g, 0.9)
    mask = ref.topk_mask(jnp.abs(v1), k)
    want = v1 * mask
    np.testing.assert_allclose(g_out, want, **TOL)
