"""L2 correctness: flat-ABI packing, model shapes, gradient sanity,
and short-horizon trainability of both model families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import cnn, lstm, model as ml, pack


# ------------------------------------------------------------ pack/unpack --


def test_pack_unpack_roundtrip_lstm():
    cfg = ml.MODELS["charlstm"]
    params = ml.init_params(cfg)
    flat = pack.pack(params)
    back = pack.unpack(flat, params)
    for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(params)[0],
        jax.tree_util.tree_flatten_with_path(back)[0],
    ):
        assert jax.tree_util.keystr(pa) == jax.tree_util.keystr(pb)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pack_unpack_roundtrip_cnn():
    cfg = ml.MODELS["resnet8"]
    params = ml.init_params(cfg)
    flat = pack.pack(params)
    back = pack.unpack(flat, params)
    np.testing.assert_array_equal(np.asarray(pack.pack(back)), np.asarray(flat))


def test_pack_order_deterministic():
    cfg = ml.MODELS["charlstm"]
    s1 = pack.spec_of(ml.init_params(cfg))
    s2 = pack.spec_of(ml.init_params(cfg))
    assert s1 == s2
    assert s1 == sorted(s1, key=lambda kv: kv[0])


def test_unpack_length_mismatch_raises():
    cfg = ml.MODELS["charlstm"]
    params = ml.init_params(cfg)
    with pytest.raises(ValueError):
        pack.unpack(jnp.zeros(pack.param_count(params) + 1), params)


def test_param_count_matches_flat_len():
    for name in ("charlstm", "resnet8"):
        cfg = ml.MODELS[name]
        assert ml.flat_init(cfg).shape[0] == ml.param_count(cfg)


# ------------------------------------------------------------- model fwd ---


def test_resnet_logits_shape():
    cfg = ml.MODELS["resnet8"]
    params = ml.init_params(cfg)
    x = jnp.zeros((4, 32, 32, 3))
    logits = cnn.resnet_apply(params, x, cfg.depth)
    assert logits.shape == (4, 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_resnet_depth_validation():
    with pytest.raises(AssertionError):
        cnn.init_resnet(jax.random.PRNGKey(0), depth=10)


@pytest.mark.parametrize("depth,nblocks", [(8, 1), (20, 3), (56, 9)])
def test_resnet_depth_block_count(depth, nblocks):
    params = cnn.init_resnet(jax.random.PRNGKey(0), depth)
    blocks = [k for k in params if k.startswith("s") and "b" in k and k != "stem"]
    assert len(blocks) == 3 * nblocks


def test_lstm_logits_shape():
    cfg = ml.MODELS["charlstm"]
    params = ml.init_params(cfg)
    x = jnp.zeros((3, cfg.seq), jnp.int32)
    logits = lstm.lstm_apply(params, x)
    assert logits.shape == (3, cfg.seq, cfg.vocab)


def test_group_norm_zero_mean_unit_var():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 16)) * 5 + 3
    y = cnn.group_norm(x, jnp.ones(16), jnp.zeros(16), groups=4)
    yg = np.asarray(y).reshape(2, 8, 8, 4, 4)
    np.testing.assert_allclose(yg.mean(axis=(1, 2, 4)), 0.0, atol=1e-4)
    np.testing.assert_allclose(yg.var(axis=(1, 2, 4)), 1.0, atol=1e-2)


# ------------------------------------------------------------ train steps --


def _rand_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.kind == "cnn":
        x = jnp.asarray(rng.normal(size=(cfg.batch, 32, 32, 3)), jnp.float32)
        y = jnp.asarray(rng.integers(0, cfg.num_classes, size=(cfg.batch,)), jnp.int32)
    else:
        x = jnp.asarray(rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq)), jnp.int32)
        y = jnp.asarray(rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq)), jnp.int32)
    return x, y


@pytest.mark.parametrize("name", ["charlstm", "resnet8"])
def test_train_step_signature(name):
    cfg = ml.MODELS[name]
    ts = jax.jit(ml.make_train_step(cfg))
    p0 = ml.flat_init(cfg)
    x, y = _rand_batch(cfg)
    loss, grads, nc = ts(p0, x, y)
    assert loss.shape == ()
    assert grads.shape == p0.shape
    assert float(jnp.linalg.norm(grads)) > 0
    total = cfg.batch * (cfg.seq if cfg.kind == "lstm" else 1)
    assert 0 <= int(nc) <= total


@pytest.mark.parametrize("name", ["charlstm", "resnet8"])
def test_eval_matches_train_metrics(name):
    cfg = ml.MODELS[name]
    ts = jax.jit(ml.make_train_step(cfg))
    ev = jax.jit(ml.make_eval_step(cfg))
    p0 = ml.flat_init(cfg)
    x, y = _rand_batch(cfg, 1)
    lt, _, nct = ts(p0, x, y)
    le, nce = ev(p0, x, y)
    np.testing.assert_allclose(float(lt), float(le), rtol=1e-5)
    assert int(nct) == int(nce)


def test_lstm_sgd_reduces_loss():
    """A few SGD steps on a fixed batch must reduce the loss (trainability)."""
    cfg = ml.MODELS["charlstm"]
    ts = jax.jit(ml.make_train_step(cfg))
    p = ml.flat_init(cfg)
    x, y = _rand_batch(cfg, 2)
    first = None
    for _ in range(20):
        loss, grads, _ = ts(p, x, y)
        if first is None:
            first = float(loss)
        p = p - 0.5 * grads
    assert float(loss) < first - 0.05, (first, float(loss))


def test_resnet_sgd_reduces_loss():
    cfg = ml.MODELS["resnet8"]
    ts = jax.jit(ml.make_train_step(cfg))
    p = ml.flat_init(cfg)
    x, y = _rand_batch(cfg, 3)
    first = None
    for _ in range(5):
        loss, grads, _ = ts(p, x, y)
        if first is None:
            first = float(loss)
        p = p - 0.05 * grads
    assert float(loss) < first, (first, float(loss))


def test_gradient_deterministic():
    cfg = ml.MODELS["charlstm"]
    ts = jax.jit(ml.make_train_step(cfg))
    p0 = ml.flat_init(cfg)
    x, y = _rand_batch(cfg, 4)
    _, g1, _ = ts(p0, x, y)
    _, g2, _ = ts(p0, x, y)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
