"""AOT pipeline checks: HLO-text conversion, manifest integrity, and
consistency between the exported init vector and the in-process model."""

import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as ml

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))), "artifacts")


def test_to_hlo_text_simple_fn():
    lowered = jax.jit(lambda x: (x * 2 + 1,)).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


def test_to_hlo_text_contains_entry_params():
    cfg = ml.MODELS["charlstm"]
    p = ml.param_count(cfg)
    xspec, yspec = ml.input_specs(cfg)
    lowered = jax.jit(ml.make_eval_step(cfg)).lower(
        jax.ShapeDtypeStruct((p,), jnp.float32), xspec, yspec
    )
    text = aot.to_hlo_text(lowered)
    assert f"f32[{p}]" in text


manifest_path = os.path.join(ART, "manifest.json")
needs_artifacts = pytest.mark.skipif(
    not os.path.exists(manifest_path), reason="run `make artifacts` first"
)


@needs_artifacts
def test_manifest_structure():
    with open(manifest_path) as f:
        man = json.load(f)
    assert man["version"] >= 2
    for name in ("resnet8", "charlstm"):
        entry = man["models"][name]
        assert entry["param_count"] > 0
        for part in ("train", "eval", "init", "gmf_score", "dgc_update"):
            path = os.path.join(ART, entry[part]["file"])
            assert os.path.exists(path), path
            assert os.path.getsize(path) == entry[part]["bytes"] or part == "init"


@needs_artifacts
def test_manifest_hashes_match_files():
    with open(manifest_path) as f:
        man = json.load(f)
    for entry in man["models"].values():
        for part in ("train", "eval", "gmf_score", "dgc_update"):
            path = os.path.join(ART, entry[part]["file"])
            with open(path, "rb") as fh:
                digest = hashlib.sha256(fh.read()).hexdigest()[:16]
            assert digest == entry[part]["sha256_16"], path


@needs_artifacts
def test_init_vector_matches_model():
    with open(manifest_path) as f:
        man = json.load(f)
    for name in ("resnet8", "charlstm"):
        entry = man["models"][name]
        path = os.path.join(ART, entry["init"]["file"])
        on_disk = np.fromfile(path, dtype="<f4")
        assert on_disk.shape[0] == entry["param_count"]
        in_proc = np.asarray(ml.flat_init(ml.MODELS[name]))
        np.testing.assert_array_equal(on_disk, in_proc)


@needs_artifacts
def test_param_counts_stable():
    """Pin the exported parameter counts: a silent change would desync the
    Rust runtime's momentum state sizes from the artifacts."""
    with open(manifest_path) as f:
        man = json.load(f)
    assert man["models"]["resnet8"]["param_count"] == 77850
    assert man["models"]["charlstm"]["param_count"] == 25920
