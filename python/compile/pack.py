"""Flat-parameter-vector ABI shared between the JAX models and Rust.

Every lowered train/eval step takes the model parameters as a single flat
``f32[P]`` vector.  The Rust coordinator only ever sees ``&[f32]`` of length
``P``: compression, momentum state, aggregation and the SGD update all
operate on the flat vector, and the mapping back to structured parameters
lives entirely inside the lowered HLO (static slicing + reshape, fused away
by XLA).

The packing order is the *sorted flattened key order* of the parameter
pytree, which is deterministic across processes and recorded in the
artifact manifest for debugging.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


Spec = List[Tuple[str, Tuple[int, ...]]]


def _flatten_with_paths(tree: Any) -> List[Tuple[str, jax.Array]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    items = [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves]
    items.sort(key=lambda kv: kv[0])
    return items


def spec_of(params: Any) -> Spec:
    """Shape spec (name, shape) for each leaf, in packing order."""
    return [(name, tuple(leaf.shape)) for name, leaf in _flatten_with_paths(params)]


def param_count(params: Any) -> int:
    return sum(int(np.prod(s)) for _, s in spec_of(params))


def pack(params: Any) -> jax.Array:
    """Pack a parameter pytree into one flat f32 vector."""
    items = _flatten_with_paths(params)
    return jnp.concatenate([leaf.reshape(-1).astype(jnp.float32) for _, leaf in items])


def unpack(flat: jax.Array, tree_template: Any) -> Any:
    """Unpack a flat f32 vector into the structure of ``tree_template``.

    Static shapes only: lowers to slices + reshapes.
    """
    items = _flatten_with_paths(tree_template)
    out: Dict[str, jax.Array] = {}
    off = 0
    for name, leaf in items:
        n = int(np.prod(leaf.shape)) if leaf.ndim else 1
        out[name] = flat[off : off + n].reshape(leaf.shape)
        off += n
    if off != flat.shape[0]:
        raise ValueError(f"flat vector length {flat.shape[0]} != spec total {off}")

    # rebuild the pytree by substituting leaves in path order
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_template)
    leaves = []
    for path, _leaf in paths:
        leaves.append(out[jax.tree_util.keystr(path)])
    return jax.tree_util.tree_unflatten(treedef, leaves)
