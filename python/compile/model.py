"""L2 public surface: model registry + flat-ABI train/eval step builders.

Each entry of :data:`MODELS` describes one lowered model variant.  The
builders return jittable functions with the flat-parameter ABI documented in
``pack.py``:

    train_step(flat_params, x, y) -> (loss, flat_grads, ncorrect)
    eval_step(flat_params, x, y)  -> (loss, ncorrect)

``aot.py`` lowers these to HLO text artifacts; the pytest suite checks their
shapes and gradient sanity before export.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from . import cnn, lstm, pack


@dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str  # "cnn" | "lstm"
    batch: int
    # cnn
    depth: int = 8
    num_classes: int = 10
    image: Tuple[int, int, int] = (32, 32, 3)
    # lstm
    vocab: int = 64
    embed: int = 16
    hidden: int = 64
    seq: int = 20
    seed: int = 0


MODELS: Dict[str, ModelConfig] = {
    # default CIFAR model: ResNet-8, tractable on the CPU testbed
    "resnet8": ModelConfig(name="resnet8", kind="cnn", batch=32, depth=8),
    # paper-scale CIFAR model (export on demand; see aot.py --models)
    "resnet20": ModelConfig(name="resnet20", kind="cnn", batch=32, depth=20),
    "resnet56": ModelConfig(name="resnet56", kind="cnn", batch=32, depth=56),
    # Shakespeare next-char LSTM
    "charlstm": ModelConfig(name="charlstm", kind="lstm", batch=16, vocab=64, embed=16, hidden=64, seq=20),
}


def init_params(cfg: ModelConfig) -> Any:
    key = jax.random.PRNGKey(cfg.seed)
    if cfg.kind == "cnn":
        return cnn.init_resnet(key, cfg.depth, cfg.num_classes)
    if cfg.kind == "lstm":
        return lstm.init_lstm(key, cfg.vocab, cfg.embed, cfg.hidden)
    raise ValueError(cfg.kind)


def apply_fn(cfg: ModelConfig) -> Callable[[Any, jax.Array], jax.Array]:
    if cfg.kind == "cnn":
        return lambda p, x: cnn.resnet_apply(p, x, cfg.depth)
    if cfg.kind == "lstm":
        return lambda p, x: lstm.lstm_apply(p, x)
    raise ValueError(cfg.kind)


def input_specs(cfg: ModelConfig) -> Tuple[jax.ShapeDtypeStruct, jax.ShapeDtypeStruct]:
    """(x, y) example specs for lowering."""
    if cfg.kind == "cnn":
        h, w, c = cfg.image
        return (
            jax.ShapeDtypeStruct((cfg.batch, h, w, c), jnp.float32),
            jax.ShapeDtypeStruct((cfg.batch,), jnp.int32),
        )
    return (
        jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32),
        jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32),
    )


def _loss_and_correct(logits: jax.Array, y: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Mean cross-entropy + number of correct predictions.

    Works for both [B, C] / y[B] (cnn) and [B, S, C] / y[B, S] (lstm).
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1).squeeze(-1)
    loss = nll.mean()
    ncorrect = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.int32))
    return loss, ncorrect


def make_train_step(cfg: ModelConfig):
    """(flat_params[P], x, y) -> (loss, flat_grads[P], ncorrect)."""
    template = init_params(cfg)
    apply = apply_fn(cfg)

    def train_step(flat_params, x, y):
        params = pack.unpack(flat_params, template)

        def loss_fn(p):
            loss, nc = _loss_and_correct(apply(p, x), y)
            return loss, nc

        (loss, nc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return loss, pack.pack(grads), nc

    return train_step


def make_eval_step(cfg: ModelConfig):
    """(flat_params[P], x, y) -> (loss, ncorrect)."""
    template = init_params(cfg)
    apply = apply_fn(cfg)

    def eval_step(flat_params, x, y):
        params = pack.unpack(flat_params, template)
        return _loss_and_correct(apply(params, x), y)

    return eval_step


def flat_init(cfg: ModelConfig) -> jax.Array:
    """The W_init shared by the server with all clients (Alg. 1 line 2)."""
    return pack.pack(init_params(cfg))


def param_count(cfg: ModelConfig) -> int:
    return pack.param_count(init_params(cfg))
