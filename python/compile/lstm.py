"""L2 model: single-layer char-LSTM for next-character prediction
(paper: "RNN (single layer LSTM)" on the Shakespeare dataset).

Standard LSTM cell with a fused gate matrix; sequence processed with
``lax.scan``.  The loss is mean cross-entropy over every position (teacher
forcing); accuracy is the fraction of correctly predicted next characters.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def init_lstm(key, vocab: int, embed: int, hidden: int) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": jax.random.normal(k1, (vocab, embed), jnp.float32) * 0.1,
        "wx": jax.random.normal(k2, (embed, 4 * hidden), jnp.float32) * np.sqrt(1.0 / embed),
        "wh": jax.random.normal(k3, (hidden, 4 * hidden), jnp.float32) * np.sqrt(1.0 / hidden),
        "b": jnp.zeros((4 * hidden,)),
        "head_w": jax.random.normal(k4, (hidden, vocab), jnp.float32) * np.sqrt(1.0 / hidden),
        "head_b": jnp.zeros((vocab,)),
    }


def lstm_apply(params: Dict[str, Any], x: jax.Array) -> jax.Array:
    """x: int32 [B, S] token ids -> logits f32 [B, S, vocab]."""
    b, s = x.shape
    hidden = params["wh"].shape[0]
    emb = params["embed"][x]  # [B, S, E]

    def cell(carry, xt):
        h, c = carry
        gates = xt @ params["wx"] + h @ params["wh"] + params["b"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    h0 = jnp.zeros((b, hidden))
    (_, _), hs = jax.lax.scan(cell, (h0, h0), jnp.swapaxes(emb, 0, 1))
    hs = jnp.swapaxes(hs, 0, 1)  # [B, S, H]
    return hs @ params["head_w"] + params["head_b"]
