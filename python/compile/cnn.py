"""L2 model: ResNet for 32x32 image classification (paper: ResNet56/CIFAR10).

Functional JAX implementation (no flax/haiku -- build environment is
jax-only).  BatchNorm is replaced by GroupNorm: federated averaging of BN
running statistics is ill-defined under non-IID data and the paper's
compression schemes act on *gradients* only; GroupNorm keeps every trainable
tensor in the gradient path with no mutable aux state (documented in
DESIGN.md substitutions).

``resnet{8,14,20,56}`` follow the classic CIFAR ResNet layout: a 3x3 stem
with 16 channels, three stages of n basic blocks at widths (16, 32, 64) with
stride-2 transitions, global average pooling and a dense head.  Depth
N = 6n + 2.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def conv_init(key, kh, kw, cin, cout):
    """He-normal initialisation for a HWIO conv kernel."""
    fan_in = kh * kw * cin
    std = np.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std


def conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def group_norm(x, scale, bias, groups=8, eps=1e-5):
    """GroupNorm over NHWC; ``groups`` clamped to the channel count."""
    n, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(n, h, w, g, c // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return xg.reshape(n, h, w, c) * scale + bias


def init_resnet(key, depth: int, num_classes: int = 10) -> Dict[str, Any]:
    """Initialise CIFAR-ResNet parameters of the given depth (6n+2)."""
    assert (depth - 2) % 6 == 0, f"depth {depth} is not 6n+2"
    n = (depth - 2) // 6
    widths = (16, 32, 64)
    keys = iter(jax.random.split(key, 4 + 6 * n * 3 + 8))

    params: Dict[str, Any] = {
        "stem": {
            "w": conv_init(next(keys), 3, 3, 3, 16),
            "gn_s": jnp.ones((16,)),
            "gn_b": jnp.zeros((16,)),
        }
    }
    cin = 16
    for si, width in enumerate(widths):
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            blk = {
                "w1": conv_init(next(keys), 3, 3, cin, width),
                "gn1_s": jnp.ones((width,)),
                "gn1_b": jnp.zeros((width,)),
                "w2": conv_init(next(keys), 3, 3, width, width),
                "gn2_s": jnp.ones((width,)),
                "gn2_b": jnp.zeros((width,)),
            }
            if stride != 1 or cin != width:
                blk["proj"] = conv_init(next(keys), 1, 1, cin, width)
            params[f"s{si}b{bi}"] = blk
            cin = width
    params["head"] = {
        "w": jax.random.normal(next(keys), (64, num_classes), jnp.float32) * np.sqrt(1.0 / 64),
        "b": jnp.zeros((num_classes,)),
    }
    params["_meta_depth"] = jnp.zeros(())  # keeps depth re-derivable? no-op leaf avoided:
    del params["_meta_depth"]
    return params


def resnet_apply(params: Dict[str, Any], x: jax.Array, depth: int) -> jax.Array:
    """Forward pass -> logits [B, num_classes]. ``x`` is NHWC f32 in [0,1]."""
    n = (depth - 2) // 6
    stem = params["stem"]
    h = conv(x, stem["w"])
    h = jax.nn.relu(group_norm(h, stem["gn_s"], stem["gn_b"]))
    for si in range(3):
        for bi in range(n):
            blk = params[f"s{si}b{bi}"]
            stride = 2 if (si > 0 and bi == 0) else 1
            y = conv(h, blk["w1"], stride)
            y = jax.nn.relu(group_norm(y, blk["gn1_s"], blk["gn1_b"]))
            y = conv(y, blk["w2"])
            y = group_norm(y, blk["gn2_s"], blk["gn2_b"])
            sc = conv(h, blk["proj"], stride) if "proj" in blk else h
            h = jax.nn.relu(y + sc)
    h = h.mean(axis=(1, 2))  # global average pool
    head = params["head"]
    return h @ head["w"] + head["b"]
