"""Pure-jnp oracles for the L1 Pallas kernels (``gmf.py``).

Every kernel has a reference implementation here written with plain
``jax.numpy`` ops only -- no Pallas, no custom control flow.  The pytest
suite asserts ``assert_allclose(kernel(x), ref(x))`` under hypothesis-driven
shape/value sweeps; the Rust-native engine is additionally checked against
the *artifacts built from the kernels*, so this file is the root of the
correctness chain:

    ref.py (spec)  ==  gmf.py (Pallas)  ==  artifacts/*.hlo.txt  ==  rust
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def normalize(x: jax.Array, eps: float = 1e-12) -> jax.Array:
    """N(x) = x / (||x||_2 + eps) -- the ``N`` of paper Eq. 2."""
    return x / (jnp.linalg.norm(x) + eps)


def sumsq(x: jax.Array) -> jax.Array:
    return jnp.sum(x * x)


def gmf_score(v: jax.Array, m: jax.Array, tau, eps: float = 1e-12) -> jax.Array:
    """Z = |(1-tau) N(V) + tau N(M)|  (paper Eq. 2, selection score)."""
    return jnp.abs((1.0 - tau) * normalize(v, eps) + tau * normalize(m, eps))


def dgc_update(u, v, grad, alpha):
    """U' = alpha U + g ; V' = V + U'  (Alg. 1 lines 6-7)."""
    u2 = alpha * u + grad
    v2 = v + u2
    return u2, v2


def mask_apply(u, v, mask):
    """G = V.mask ; U' = U.(1-mask) ; V' = V.(1-mask)  (lines 10-12)."""
    return v * mask, u * (1.0 - mask), v * (1.0 - mask)


def topk_mask(z: jax.Array, k: int) -> jax.Array:
    """{0,1} mask keeping the k largest entries of z (ties: >= threshold)."""
    thresh = jax.lax.top_k(z, k)[0][-1]
    return (z >= thresh).astype(jnp.float32)


def dgc_gmf_step(u, v, m, grad, ghat_prev, alpha, beta, tau, k: int):
    """Reference for the composite client round (Alg. 1 lines 6-12)."""
    m2 = beta * m + ghat_prev
    u1, v1 = dgc_update(u, v, grad, alpha)
    z = gmf_score(v1, m2, tau)
    mask = topk_mask(z, k)
    g_out, u2, v2 = mask_apply(u1, v1, mask)
    thresh = jax.lax.top_k(z, k)[0][-1]
    return g_out, u2, v2, m2, thresh
