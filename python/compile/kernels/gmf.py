"""L1 Pallas kernels for the Global Momentum Fusion compression pipeline.

These kernels implement the compute hot-spot of the paper (Kuo et al. 2022,
Algorithm 1): the per-round, per-client elementwise passes over the flat
parameter-sized vectors U (momentum-corrected gradient), V (residual
accumulator) and M (client-tracked global momentum).

All kernels operate on flat f32 vectors padded to a multiple of BLOCK
(8*128 = 1024, the TPU-aligned tile).  On TPU each block is one VMEM tile
and the grid walks the HBM->VMEM schedule; here we lower with
``interpret=True`` so the same HLO runs on the CPU PJRT client (see
DESIGN.md "Hardware adaptation").

Kernels
-------
- ``sumsq``             : blockwise sum-of-squares partials (phase 1 of the
                          L2 normalisation used by ``N`` in paper Eq. 2)
- ``gmf_fuse``          : Z = |(1-tau) * V * inv_nv + tau * M * inv_nm|
                          (phase 2 of Eq. 2, fused scale+lerp+abs)
- ``dgc_update``        : U' = alpha*U + grad ; V' = V + U'
                          (momentum correction, Alg. 1 lines 6-7)
- ``mask_apply``        : G = V (.) mask ; U' = U (.) (1-mask) ;
                          V' = V (.) (1-mask)   (Alg. 1 lines 10-12)

Correctness oracle: ``ref.py`` (pure jnp), checked by
``python/tests/test_kernel.py`` under hypothesis sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One TPU-aligned tile of f32: 8 sublanes x 128 lanes.
BLOCK = 1024

# All pallas_call sites use interpret mode: real TPU lowering emits a Mosaic
# custom-call the CPU PJRT plugin cannot execute.
INTERPRET = True


def pad_to_block(x: jax.Array) -> jax.Array:
    """Pad a flat vector with zeros to a multiple of BLOCK."""
    n = x.shape[0]
    rem = (-n) % BLOCK
    if rem:
        x = jnp.pad(x, (0, rem))
    return x


def _grid(n: int) -> int:
    assert n % BLOCK == 0, f"padded length {n} not a multiple of {BLOCK}"
    return n // BLOCK


# ---------------------------------------------------------------------------
# sumsq: blockwise sum of squares (reduction phase of L2 normalisation)
# ---------------------------------------------------------------------------


def _sumsq_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[0] = jnp.sum(x * x)


def sumsq(x: jax.Array) -> jax.Array:
    """Sum of squares of a flat f32 vector, via blockwise partials.

    Returns a scalar.  The blockwise partials are the structure that maps to
    a VMEM-resident per-tile reduction on TPU; the final (grid-sized) sum is
    left to XLA.
    """
    x = pad_to_block(x)
    g = _grid(x.shape[0])
    partials = pl.pallas_call(
        _sumsq_kernel,
        out_shape=jax.ShapeDtypeStruct((g,), jnp.float32),
        grid=(g,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        interpret=INTERPRET,
    )(x)
    return jnp.sum(partials)


# ---------------------------------------------------------------------------
# gmf_fuse: Z = |(1-tau) * v * inv_nv + tau * m * inv_nm|   (paper Eq. 2)
# ---------------------------------------------------------------------------


def _gmf_fuse_kernel(scal_ref, v_ref, m_ref, z_ref):
    inv_nv = scal_ref[0]
    inv_nm = scal_ref[1]
    tau = scal_ref[2]
    v = v_ref[...]
    m = m_ref[...]
    z_ref[...] = jnp.abs((1.0 - tau) * v * inv_nv + tau * m * inv_nm)


def gmf_fuse(v: jax.Array, m: jax.Array, inv_nv, inv_nm, tau) -> jax.Array:
    """Fused normalise-lerp-abs over flat padded vectors.

    ``inv_nv``/``inv_nm`` are the reciprocal L2 norms (scalars), ``tau`` the
    fusion ratio.  The three scalars travel in one (3,) array broadcast to
    every block (SMEM-resident on TPU).
    """
    assert v.shape == m.shape
    n = v.shape[0]
    g = _grid(n)
    scal = jnp.stack(
        [jnp.asarray(inv_nv, jnp.float32), jnp.asarray(inv_nm, jnp.float32), jnp.asarray(tau, jnp.float32)]
    )
    return pl.pallas_call(
        _gmf_fuse_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=INTERPRET,
    )(scal, v, m)


def gmf_score(v: jax.Array, m: jax.Array, tau, eps: float = 1e-12) -> jax.Array:
    """Full paper Eq. 2 selection score: Z = |(1-tau)N(V) + tau N(M)|.

    ``N(x) = x / (||x||_2 + eps)``.  Inputs are unpadded flat vectors; the
    result is unpadded again.  Composes the two kernel phases.
    """
    n = v.shape[0]
    vp, mp = pad_to_block(v), pad_to_block(m)
    inv_nv = 1.0 / (jnp.sqrt(sumsq(vp)) + eps)
    inv_nm = 1.0 / (jnp.sqrt(sumsq(mp)) + eps)
    z = gmf_fuse(vp, mp, inv_nv, inv_nm, tau)
    return z[:n]


# ---------------------------------------------------------------------------
# dgc_update: U' = alpha*U + grad ; V' = V + U'   (Alg. 1 lines 6-7)
# ---------------------------------------------------------------------------


def _dgc_update_kernel(scal_ref, u_ref, v_ref, g_ref, u_out, v_out):
    alpha = scal_ref[0]
    u_new = alpha * u_ref[...] + g_ref[...]
    u_out[...] = u_new
    v_out[...] = v_ref[...] + u_new


def dgc_update(u: jax.Array, v: jax.Array, grad: jax.Array, alpha):
    """Momentum correction: returns (U', V') with U'=alpha*U+g, V'=V+U'."""
    n = u.shape[0]
    up, vp, gp = pad_to_block(u), pad_to_block(v), pad_to_block(grad)
    g = _grid(up.shape[0])
    scal = jnp.asarray(alpha, jnp.float32).reshape(1)
    u2, v2 = pl.pallas_call(
        _dgc_update_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(up.shape, jnp.float32),
            jax.ShapeDtypeStruct(vp.shape, jnp.float32),
        ),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=(
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ),
        interpret=INTERPRET,
    )(scal, up, vp, gp)
    return u2[:n], v2[:n]


# ---------------------------------------------------------------------------
# mask_apply: G = V.mask ; U' = U.(1-mask) ; V' = V.(1-mask)  (lines 10-12)
# ---------------------------------------------------------------------------


def _mask_apply_kernel(u_ref, v_ref, mask_ref, g_out, u_out, v_out):
    mask = mask_ref[...]
    keep = 1.0 - mask
    v = v_ref[...]
    g_out[...] = v * mask
    u_out[...] = u_ref[...] * keep
    v_out[...] = v * keep


def mask_apply(u: jax.Array, v: jax.Array, mask: jax.Array):
    """Memory update given a {0,1} mask: returns (G, U', V')."""
    n = u.shape[0]
    up, vp, mp = pad_to_block(u), pad_to_block(v), pad_to_block(mask)
    g = _grid(up.shape[0])
    outs = pl.pallas_call(
        _mask_apply_kernel,
        out_shape=tuple(jax.ShapeDtypeStruct(up.shape, jnp.float32) for _ in range(3)),
        grid=(g,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))] * 3,
        out_specs=tuple(pl.BlockSpec((BLOCK,), lambda i: (i,)) for _ in range(3)),
        interpret=INTERPRET,
    )(up, vp, mp)
    gv, u2, v2 = outs
    return gv[:n], u2[:n], v2[:n]


# ---------------------------------------------------------------------------
# Composite client-side compression step (Alg. 1 lines 6-12), exported as a
# single artifact so the L3 hot path can run one executable per round.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k",))
def dgc_gmf_step(u, v, m, grad, ghat_prev, alpha, beta, tau, k: int):
    """One full DGCwGMF client compression round (paper Algorithm 1).

    Args:
      u, v:       momentum correction state (flat f32[P])
      m:          client-tracked global momentum (flat f32[P])
      grad:       fresh local gradient (flat f32[P])
      ghat_prev:  previous round's aggregated gradient (flat f32[P])
      alpha/beta: local/global momentum factors
      tau:        fusion ratio (tau=0 degenerates to DGC)
      k:          number of coordinates to keep (static)

    Returns (g_sparse_dense, u', v', m', threshold) where g_sparse_dense is
    the dense vector with only the selected coordinates nonzero.
    """
    m2 = beta * m + ghat_prev  # Alg. 1 line 8 (global momentum accumulate)
    u1, v1 = dgc_update(u, v, grad, alpha)  # lines 6-7
    z = gmf_score(v1, m2, tau)  # line 9 (GMF)
    # top-k mask from the fused score; selection itself is XLA's top_k (it is
    # selection-bound, not FLOP-bound -- see DESIGN.md Hardware adaptation).
    thresh = jax.lax.top_k(z, k)[0][-1]
    mask = (z >= thresh).astype(jnp.float32)
    g_out, u2, v2 = mask_apply(u1, v1, mask)  # lines 10-12
    return g_out, u2, v2, m2, thresh
