"""AOT pipeline: lower L2/L1 jax functions to HLO-text artifacts for Rust.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` rust crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per model variant:
    artifacts/<name>_train.hlo.txt   (params[P], x, y) -> (loss, grads[P], ncorrect)
    artifacts/<name>_eval.hlo.txt    (params[P], x, y) -> (loss, ncorrect)
    artifacts/<name>_init.f32        initial flat params, little-endian f32
plus the compression-kernel artifacts at each model's P:
    artifacts/<name>_gmf_score.hlo.txt   (V[P], M[P], tau[]) -> Z[P]
    artifacts/<name>_dgc_update.hlo.txt  (U[P], V[P], grad[P], alpha[]) -> (U', V')
and artifacts/manifest.json describing everything for the Rust runtime.

Usage:  python -m compile.aot --out-dir ../artifacts [--models resnet8,charlstm]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib
from .kernels import gmf

MANIFEST_VERSION = 2
DEFAULT_MODELS = "resnet8,charlstm"


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to XLA HLO text (return_tuple=True ABI)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> Dict[str, Any]:
    with open(path, "w") as f:
        f.write(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    return {"file": os.path.basename(path), "bytes": len(text), "sha256_16": digest}


def lower_model(cfg: model_lib.ModelConfig, out_dir: str) -> Dict[str, Any]:
    p = model_lib.param_count(cfg)
    pspec = jax.ShapeDtypeStruct((p,), jnp.float32)
    xspec, yspec = model_lib.input_specs(cfg)

    train = jax.jit(model_lib.make_train_step(cfg))
    evalf = jax.jit(model_lib.make_eval_step(cfg))

    entry: Dict[str, Any] = {
        "name": cfg.name,
        "kind": cfg.kind,
        "param_count": p,
        "batch": cfg.batch,
        "inputs": {
            "x": {"shape": list(xspec.shape), "dtype": str(xspec.dtype)},
            "y": {"shape": list(yspec.shape), "dtype": str(yspec.dtype)},
        },
    }
    if cfg.kind == "lstm":
        entry["vocab"] = cfg.vocab
        entry["seq"] = cfg.seq
    if cfg.kind == "cnn":
        entry["num_classes"] = cfg.num_classes
        entry["image"] = list(cfg.image)

    entry["train"] = _write(
        os.path.join(out_dir, f"{cfg.name}_train.hlo.txt"),
        to_hlo_text(train.lower(pspec, xspec, yspec)),
    )
    entry["eval"] = _write(
        os.path.join(out_dir, f"{cfg.name}_eval.hlo.txt"),
        to_hlo_text(evalf.lower(pspec, xspec, yspec)),
    )

    # initial parameters (W_init, Alg. 1 line 2) as raw little-endian f32
    init = np.asarray(model_lib.flat_init(cfg), dtype="<f4")
    init_path = os.path.join(out_dir, f"{cfg.name}_init.f32")
    init.tofile(init_path)
    entry["init"] = {
        "file": os.path.basename(init_path),
        "bytes": init.nbytes,
        "sha256_16": hashlib.sha256(init.tobytes()).hexdigest()[:16],
    }

    # L1 compression kernels at this model's P (flat ABI; scalar hyper-params
    # travel as 0-d f32 inputs)
    vec = jax.ShapeDtypeStruct((p,), jnp.float32)
    scal = jax.ShapeDtypeStruct((), jnp.float32)

    score = jax.jit(lambda v, m, tau: gmf.gmf_score(v, m, tau))
    entry["gmf_score"] = _write(
        os.path.join(out_dir, f"{cfg.name}_gmf_score.hlo.txt"),
        to_hlo_text(score.lower(vec, vec, scal)),
    )

    upd = jax.jit(lambda u, v, g, alpha: gmf.dgc_update(u, v, g, alpha))
    entry["dgc_update"] = _write(
        os.path.join(out_dir, f"{cfg.name}_dgc_update.hlo.txt"),
        to_hlo_text(upd.lower(vec, vec, vec, scal)),
    )
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=DEFAULT_MODELS, help="comma-separated model names")
    ap.add_argument("--out", default=None, help="(compat) single-file target; ignored")
    args = ap.parse_args()

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    manifest: Dict[str, Any] = {
        "version": MANIFEST_VERSION,
        "jax": jax.__version__,
        "block": gmf.BLOCK,
        "models": {},
    }
    for name in [m.strip() for m in args.models.split(",") if m.strip()]:
        cfg = model_lib.MODELS[name]
        print(f"[aot] lowering {name} ...", flush=True)
        manifest["models"][name] = lower_model(cfg, out_dir)
        print(f"[aot]   P={manifest['models'][name]['param_count']}", flush=True)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
